//! Parallel decompression (paper §2.3 "Data decompression"): fetch the
//! chunk containing the target block, stage-2 inflate it (cached), then
//! stage-1 decode the block.
//!
//! Four access paths:
//! * **Random access** via [`BlockReader::read_block`] — decoded chunks
//!   live in a sharded concurrent [`ChunkCache`]
//!   ([`super::chunk_cache`]). A reader owns a small private cache by
//!   default; [`BlockReader::with_shared_cache`] attaches it to a cache
//!   shared across handles (what `.czs` datasets do), so visualization
//!   readers fanning out over quantities neither serialize on one lock
//!   nor re-decode what a sibling already inflated. Evicted sole-owner
//!   buffers are recycled, keeping the warm path allocation-free.
//! * **Whole-field** via [`decompress_field_mt`] — chunks are pulled off
//!   the same shared atomic work queue the compressor uses
//!   ([`crate::cluster::SpanQueue`]); each worker inflates and decodes
//!   its chunks into worker-owned buffers and scatters the blocks into
//!   the output field (disjoint by construction, validated up front).
//!   The serial [`decompress_field`] remains bit-identical to it.
//! * **Wide whole-field** — when the archive has fewer chunks than
//!   workers (single-chunk files, visualization extracts) *and* its
//!   chunks actually split into sub-frames (format v3), chunk-granular
//!   scheduling starves; the wide path instead fans out *inside* each
//!   chunk: the sub-frames inflate concurrently into disjoint slices,
//!   then the blocks stage-1 decode concurrently. Bit identical to the
//!   serial path, and the reason a one-chunk archive now scales with
//!   threads at all. Unframed few-chunk archives keep the chunk-granular
//!   path (their stage-2 streams cannot split), single-chunk ones still
//!   go wide for the parallel block decode.
//! * **Multi-section fan-out** via [`decompress_sections`] (what
//!   `Engine::decompress_dataset` and `.czs` whole-quantity reads
//!   drive) — many independent `.czb` sections decode concurrently on
//!   one executor: workers sweep the sections with staggered starting
//!   points (worker *t* begins at section *t*), the first to arrive at
//!   a section loads its bytes (lazy archive I/O) and opens it, and
//!   every worker steals chunk spans from whichever sections are open —
//!   so several section loads proceed concurrently, section *i+1*'s
//!   inflate overlaps section *i*'s block decode, and nobody idles at
//!   per-quantity barriers.
//!   Decoded chunks route through the shared [`ChunkCache`], keyed by
//!   each section's [`StreamId`], so whole-quantity decodes and random
//!   block access reuse each other's work. Bit-identical to decoding
//!   each section alone.
//!
//! Every decode path keeps its queue, abort flag and error state local
//! to the call, so any number of threads may submit decodes onto one
//! persistent pool concurrently (the multi-generation
//! [`crate::cluster::WorkerPool`]): a corrupt stream aborts only its own
//! submission's workers, never a sibling's.
//!
//! Stage 2 dispatches through the [`crate::codec::stage2`] registry;
//! every inflate passes the exact expected size as the decode limit, so
//! corrupt streams can neither overrun nor size an allocation.
use super::chunk_cache::{ChunkCache, DecodedChunk, StreamId};
use super::compressor::{eps_abs_of, WaveletEngine};
use super::format::{ChunkEntry, CzbFile, ShuffleMode};
use super::stage1::{codec_for, Stage1Scratch};
use crate::cluster::{self, Execute, ScopedExec, SpanQueue};
use crate::codec::shuffle;
use crate::codec::stage2::{self, decompress_framed, parse_frame_table, Stage2Codec};
use crate::core::block::{Block, BlockGrid};
use crate::core::Field3;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Resolve the registered stage-2 codec of a parsed file.
fn stage2_of(file: &CzbFile) -> &'static dyn Stage2Codec {
    stage2::by_id(file.stage2.id()).expect("parsed headers only carry registered codec ids")
}

/// Stage-2 inflate a chunk payload into `out` (serial): framed (v3) or
/// legacy monolithic (v≤2), always length-checked against the expected
/// uncompressed size.
fn inflate_payload(
    file: &CzbFile,
    codec: &dyn Stage2Codec,
    payload: &[u8],
    expect: usize,
    out: &mut Vec<u8>,
) -> Result<(), String> {
    if file.frame_raw > 0 {
        decompress_framed(codec, payload, expect, file.frame_raw as usize, out)
    } else {
        let before = out.len();
        codec.decompress_into(payload, expect, out)?;
        if out.len() - before != expect {
            return Err(format!(
                "chunk decoded to {} bytes, expected {expect}",
                out.len() - before
            ));
        }
        Ok(())
    }
}

/// Walk the u32 size prefixes of a chunk's raw block stream into
/// per-block (offset, size) pairs.
fn walk_block_prefixes(
    raw: &[u8],
    nblocks: u32,
    offsets: &mut Vec<(usize, usize)>,
) -> Result<(), String> {
    offsets.clear();
    let mut pos = 0usize;
    for _ in 0..nblocks {
        if raw.len() < pos + 4 {
            return Err("chunk truncated at block prefix".into());
        }
        let size = u32::from_le_bytes(raw[pos..pos + 4].try_into().unwrap()) as usize;
        pos += 4;
        if raw.len() < pos + size {
            return Err("chunk truncated inside block".into());
        }
        offsets.push((pos, size));
        pos += size;
    }
    Ok(())
}

/// Reject a chunk-index `rawsize` no legitimate encoder could have
/// produced, *before* it sizes any buffer: every stage-1 block payload is
/// at most a small constant factor of the block's raw samples (the
/// wavelet scheme adds a mask header, coefficient codecs can expand a
/// little), so 16 bytes per sample plus slack per block is a generous
/// ceiling. Without this, a crafted index entry (rawsize = u32::MAX on a
/// tiny payload) would drive a multi-GiB reserve even though every
/// stage-2 stream is limit-checked.
fn check_rawsize(file: &CzbFile, entry: &ChunkEntry, idx: usize) -> Result<(), String> {
    let vol = (file.bs as u128).pow(3);
    let bound = (entry.nblocks as u128) * (16 * vol + 1024);
    if (entry.rawsize as u128) > bound {
        return Err(format!(
            "chunk {idx}: rawsize {} exceeds plausible bound {bound} for {} blocks of {}^3",
            entry.rawsize, entry.nblocks, file.bs
        ));
    }
    Ok(())
}

/// Stage-2 decode chunk `idx` into reusable buffers: `tmp` holds the
/// inflated stream when unshuffling is needed, `raw` ends up with the
/// (unshuffled) raw block stream and `offsets` with the per-block
/// (offset, size) pairs. Allocation-free once the buffers are warm.
fn decode_chunk_into(
    file: &CzbFile,
    codec: &dyn Stage2Codec,
    payload: &[u8],
    idx: usize,
    tmp: &mut Vec<u8>,
    raw: &mut Vec<u8>,
    offsets: &mut Vec<(usize, usize)>,
) -> Result<(), String> {
    let entry = &file.chunks[idx];
    check_rawsize(file, entry, idx)?;
    verify_chunk_crc(file, payload, idx)?;
    let expect = file.chunk_stage2_len(entry);
    raw.clear();
    match file.shuffle {
        ShuffleMode::None => inflate_payload(file, codec, payload, expect, raw)
            .map_err(|e| format!("chunk {idx}: {e}"))?,
        ShuffleMode::Byte4 => {
            tmp.clear();
            inflate_payload(file, codec, payload, expect, tmp)
                .map_err(|e| format!("chunk {idx}: {e}"))?;
            shuffle::byte_unshuffle_into(tmp, 4, raw);
        }
        ShuffleMode::Bit4 => {
            tmp.clear();
            inflate_payload(file, codec, payload, expect, tmp)
                .map_err(|e| format!("chunk {idx}: {e}"))?;
            // the plane layout depends on the element count, which the
            // exact-length inflate above already pinned to the index
            let rawsize = entry.rawsize as usize;
            if tmp.len() != shuffle::bit_shuffled_len(rawsize, 4) {
                return Err(format!(
                    "chunk {idx}: bit-shuffled size {} inconsistent with raw size {rawsize}",
                    tmp.len()
                ));
            }
            shuffle::bit_unshuffle_into(tmp, 4, rawsize / 4, raw);
        }
    }
    if raw.len() != entry.rawsize as usize {
        return Err(format!(
            "chunk {idx}: raw size {} != index {}",
            raw.len(),
            entry.rawsize
        ));
    }
    walk_block_prefixes(raw, entry.nblocks, offsets)
}

/// Decode one stage-1 block payload into bs³ floats via the registered
/// [`super::stage1::Stage1Codec`]; `scratch` is reused across blocks so
/// the steady state allocates nothing (the fpc schemes decode through
/// their `_into` variants into scratch buffers).
fn decode_block_payload(
    file: &CzbFile,
    payload: &[u8],
    engine: &dyn WaveletEngine,
    scratch: &mut Stage1Scratch,
    out: &mut [f32],
) -> Result<(), String> {
    let bs = file.bs as usize;
    debug_assert_eq!(out.len(), bs * bs * bs);
    codec_for(&file.stage1).decode_block(&file.stage1, payload, bs, engine, scratch, out)
}

/// Build the block grid for a parsed file, rejecting (rather than
/// panicking on) inconsistent headers.
fn grid_for(file: &CzbFile, field: &Field3) -> Result<BlockGrid, String> {
    let bs = file.bs as usize;
    if bs < 4 || !bs.is_power_of_two() {
        return Err(format!("bad block size {bs}"));
    }
    if field.nx % bs != 0 || field.ny % bs != 0 || field.nz % bs != 0 {
        return Err(format!(
            "dims {}x{}x{} not divisible by block size {bs}",
            field.nx, field.ny, field.nz
        ));
    }
    let grid = BlockGrid::new(field, bs);
    if grid.nblocks() != file.nblocks as usize {
        return Err(format!(
            "header nblocks {} != grid {}",
            file.nblocks,
            grid.nblocks()
        ));
    }
    Ok(grid)
}

/// Check that the chunk index tiles `0..nblocks` exactly — the invariant
/// the compressor guarantees and the parallel decoder's disjoint-write
/// safety relies on.
fn validate_chunk_index(file: &CzbFile) -> Result<(), String> {
    let mut next = 0u32;
    for (i, c) in file.chunks.iter().enumerate() {
        if c.first_block != next {
            return Err(format!(
                "chunk {i}: first_block {} != expected {next}",
                c.first_block
            ));
        }
        next = next
            .checked_add(c.nblocks)
            .ok_or_else(|| "chunk block count overflow".to_string())?;
    }
    if next != file.nblocks {
        return Err(format!("chunks cover {next} of {} blocks", file.nblocks));
    }
    Ok(())
}

/// Verify a chunk payload against its stored CRC32C. v≥4 archives carry
/// one digest per chunk ([`CzbFile::chunk_crcs`]); older files carry
/// none and skip the check (their decode stays bit-identical). Runs
/// before any inflate, so a flipped payload bit is classified as a
/// checksum mismatch instead of surfacing as a downstream codec error —
/// or worse, silently wrong floats under a codec that cannot notice.
fn verify_chunk_crc(file: &CzbFile, payload: &[u8], idx: usize) -> Result<(), String> {
    if file.version >= 4 {
        if let Some(&want) = file.chunk_crcs.get(idx) {
            let got = crate::util::crc32c::crc32c(payload);
            if got != want {
                return Err(format!(
                    "chunk {idx}: payload checksum mismatch (stored {want:#010x}, computed {got:#010x})"
                ));
            }
        }
    }
    Ok(())
}

/// Bounds-checked slice of one chunk's compressed payload.
fn chunk_payload<'a>(bytes: &'a [u8], entry: &ChunkEntry) -> Result<&'a [u8], String> {
    let lo = entry.offset as usize;
    let hi = lo
        .checked_add(entry.csize as usize)
        .ok_or_else(|| "chunk offset overflow".to_string())?;
    if bytes.len() < hi {
        return Err("payload truncated".into());
    }
    Ok(&bytes[lo..hi])
}

/// Random-access block reader over a sharded concurrent chunk cache
/// (paper: "we keep recently decompressed chunks of blocks in a cache").
/// Private cache by default; attach to a shared one with
/// [`BlockReader::with_shared_cache`]. Buffers of evicted sole-owner
/// chunks are recycled into the next decode, so a warm reader allocates
/// nothing per miss.
pub struct BlockReader<'a> {
    pub file: CzbFile,
    payload: &'a [u8],
    engine: &'a dyn WaveletEngine,
    stage2: &'static dyn Stage2Codec,
    cache: Arc<ChunkCache>,
    stream: StreamId,
    /// stage-2 inflate scratch shared by all chunk decodes on this reader
    inflate_tmp: Vec<u8>,
    /// buffers reclaimed from the most recently evicted chunk
    spare: Option<(Vec<u8>, Vec<(usize, usize)>)>,
    /// stage-1 decode scratch shared by all block decodes on this reader
    scratch: Stage1Scratch,
    /// Per-reader cache statistics (the shared cache keeps global ones).
    pub cache_hits: usize,
    pub cache_misses: usize,
}

impl<'a> BlockReader<'a> {
    pub fn new(bytes: &'a [u8], engine: &'a dyn WaveletEngine) -> Result<Self, String> {
        let (file, _header_len) = CzbFile::parse_header(bytes)?;
        let stage2 = stage2_of(&file);
        let cache = Arc::new(ChunkCache::new(8));
        let stream = cache.register_stream();
        Ok(Self {
            file,
            payload: bytes,
            engine,
            stage2,
            cache,
            stream,
            inflate_tmp: Vec::new(),
            spare: None,
            scratch: Stage1Scratch::default(),
            cache_hits: 0,
            cache_misses: 0,
        })
    }

    /// Replace the private cache with a fresh one of roughly `cap`
    /// decoded chunks.
    pub fn with_cache_capacity(mut self, cap: usize) -> Self {
        self.cache = Arc::new(ChunkCache::new(cap));
        self.stream = self.cache.register_stream();
        self
    }

    /// Attach this reader to a cache shared with other readers. `stream`
    /// identifies the compressed quantity: readers over the *same* bytes
    /// should pass the same id (their decodes become interchangeable),
    /// distinct quantities need distinct ids
    /// ([`ChunkCache::register_stream`]).
    pub fn with_shared_cache(mut self, cache: Arc<ChunkCache>, stream: StreamId) -> Self {
        self.cache = cache;
        self.stream = stream;
        self
    }

    /// The cache this reader resolves chunks through.
    pub fn cache(&self) -> &Arc<ChunkCache> {
        &self.cache
    }

    fn chunk_of_block(&self, block_id: u32) -> Result<usize, String> {
        // chunks are sorted by first_block
        let idx = self
            .file
            .chunks
            .partition_point(|c| c.first_block <= block_id)
            .checked_sub(1)
            .ok_or("block before first chunk")?;
        let c = &self.file.chunks[idx];
        if block_id < c.first_block + c.nblocks {
            Ok(idx)
        } else {
            Err(format!("block {block_id} not covered by any chunk"))
        }
    }

    fn get_chunk(&mut self, idx: usize) -> Result<Arc<DecodedChunk>, String> {
        if let Some(c) = self.cache.get(self.stream, idx as u32) {
            self.cache_hits += 1;
            return Ok(c);
        }
        self.cache_misses += 1;
        let entry = self.file.chunks[idx];
        let payload = chunk_payload(self.payload, &entry)?;
        // decode first (into buffers recycled from the previous eviction),
        // so a corrupt chunk never costs a healthy cached one
        let (mut raw, mut offsets) = self.spare.take().unwrap_or_default();
        if let Err(e) = decode_chunk_into(
            &self.file,
            self.stage2,
            payload,
            idx,
            &mut self.inflate_tmp,
            &mut raw,
            &mut offsets,
        ) {
            self.spare = Some((raw, offsets));
            return Err(e);
        }
        let decoded =
            Arc::new(DecodedChunk { raw, block_offsets: offsets, first_block: entry.first_block });
        if let Some(bufs) = self.cache.insert(self.stream, idx as u32, decoded.clone()) {
            self.spare = Some(bufs);
        }
        Ok(decoded)
    }

    /// Decode block `block_id` into `out` (bs³ floats).
    pub fn read_block(&mut self, block_id: u32, out: &mut [f32]) -> Result<(), String> {
        if block_id >= self.file.nblocks {
            return Err(format!("block {block_id} out of range {}", self.file.nblocks));
        }
        let cidx = self.chunk_of_block(block_id)?;
        let chunk = self.get_chunk(cidx)?;
        let local = (block_id - chunk.first_block) as usize;
        if local >= chunk.block_offsets.len() {
            return Err(format!("block {block_id} missing from its chunk"));
        }
        let (off, size) = chunk.block_offsets[local];
        let engine = self.engine;
        decode_block_payload(&self.file, &chunk.raw[off..off + size], engine, &mut self.scratch, out)
    }
}

/// Raw pointer to the output field for disjoint parallel block scatters.
/// SAFETY: senders must guarantee each block id is written by exactly one
/// worker ([`validate_chunk_index`] + the span queue's disjoint pulls).
struct FieldWriter {
    ptr: *mut f32,
    len: usize,
}

unsafe impl Send for FieldWriter {}
unsafe impl Sync for FieldWriter {}

impl FieldWriter {
    /// # Safety
    /// `id` must be in range for `grid`, `grid` must describe the field
    /// behind `ptr`, `block` must hold bs³ values, and no other thread
    /// may write the same block concurrently.
    unsafe fn insert_block(&self, grid: &BlockGrid, id: usize, block: &[f32]) {
        let bs = grid.bs;
        debug_assert_eq!(block.len(), bs * bs * bs);
        // same addressing as the safe BlockGrid::insert — one source of
        // truth for the field layout
        let layout = grid.layout(id);
        for z in 0..bs {
            for y in 0..bs {
                let dst = layout.row_offset(z, y);
                debug_assert!(dst + bs <= self.len);
                std::ptr::copy_nonoverlapping(
                    block.as_ptr().add((z * bs + y) * bs),
                    self.ptr.add(dst),
                    bs,
                );
            }
        }
    }
}

/// Raw pointer to a byte buffer for disjoint parallel frame scatters.
/// SAFETY: frame raw spans tile the buffer without overlap
/// ([`stage2::frame_span`] arithmetic) and each frame is decoded by
/// exactly one worker.
struct SliceWriter {
    ptr: *mut u8,
    len: usize,
}

unsafe impl Send for SliceWriter {}
unsafe impl Sync for SliceWriter {}

impl SliceWriter {
    /// # Safety
    /// `offset + bytes.len()` must lie within the buffer and no other
    /// thread may write an overlapping range concurrently.
    unsafe fn write_at(&self, offset: usize, bytes: &[u8]) {
        debug_assert!(offset + bytes.len() <= self.len);
        std::ptr::copy_nonoverlapping(bytes.as_ptr(), self.ptr.add(offset), bytes.len());
    }
}

/// Decompress the whole field from serialized `.czb` bytes (serial path;
/// bit-identical to [`decompress_field_mt`]).
pub fn decompress_field(
    bytes: &[u8],
    engine: &dyn WaveletEngine,
) -> Result<(Field3, CzbFile), String> {
    let mut reader = BlockReader::new(bytes, engine)?.with_cache_capacity(4);
    let file = reader.file.clone();
    let bs = file.bs as usize;
    let mut field = Field3::zeros(file.nx as usize, file.ny as usize, file.nz as usize);
    let grid = grid_for(&file, &field)?;
    let mut block = Block::zeros(bs);
    for id in 0..file.nblocks {
        reader.read_block(id, &mut block.data)?;
        grid.insert(&mut field, id as usize, &block);
    }
    Ok((field, file))
}

/// Whole-field decompression parallelized over `nthreads` workers (paper
/// §2.3 "parallel decompression") — across chunks when the archive has
/// enough of them, across one chunk's sub-frames and blocks when it does
/// not.
///
/// Deprecated entry point: one-shot convenience that spawns scoped
/// workers per call; sessions should use `Engine::decompress`, which
/// drives the same core over a persistent pool.
pub fn decompress_field_mt(
    bytes: &[u8],
    engine: &dyn WaveletEngine,
    nthreads: usize,
) -> Result<(Field3, CzbFile), String> {
    decompress_field_core(&ScopedExec, bytes, engine, nthreads)
}

/// Whole-field parallel decompression on the given executor. Picks the
/// chunk-parallel path when chunks outnumber workers, the intra-chunk
/// wide path otherwise; both are bit-identical to [`decompress_field`].
pub(crate) fn decompress_field_core(
    exec: &dyn Execute,
    bytes: &[u8],
    engine: &dyn WaveletEngine,
    nthreads: usize,
) -> Result<(Field3, CzbFile), String> {
    let (file, _header_len) = CzbFile::parse_header(bytes)?;
    let nchunks = file.chunks.len();
    let nthreads = nthreads.max(1);
    if nthreads <= 1 || nchunks == 0 {
        return decompress_field(bytes, engine);
    }
    validate_chunk_index(&file)?;
    let mut field = Field3::zeros(file.nx as usize, file.ny as usize, file.nz as usize);
    // grid_for validates bs before anything cubes it
    let grid = grid_for(&file, &field)?;
    // Does any chunk actually split into several sub-frames? Unframed
    // legacy archives (and v3 files whose frames are chunk-sized) gain
    // no stage-2 parallelism from the wide path, so starved-but-multiple
    // chunks are still better decoded chunk-granular.
    let multi_frame = file.frame_raw > 0
        && file
            .chunks
            .iter()
            .any(|e| file.chunk_stage2_len(e) > file.frame_raw as usize);
    if nchunks >= nthreads || !(multi_frame || nchunks == 1) {
        decompress_chunks_parallel(exec, bytes, &file, &grid, engine, nthreads, &mut field)?;
    } else {
        decompress_chunks_wide(exec, bytes, &file, &grid, engine, nthreads, &mut field)?;
    }
    Ok((field, file))
}

/// What an integrity walk or salvage decode found, chunk by chunk.
/// Produced by [`verify_stream`] (checksum-only), the salvage decoders
/// ([`decompress_field_salvage`], `Engine::decompress_salvage`), and
/// `czb verify`.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct DecodeReport {
    /// Chunks the stream's index declares.
    pub total_chunks: usize,
    /// `(chunk index, error)` for every chunk that failed its checksum,
    /// bounds check or decode — sorted by index, at most one entry per
    /// chunk, empty for a clean stream.
    pub corrupt_chunks: Vec<(usize, String)>,
    /// Blocks belonging to the corrupt chunks (zero-filled by salvage).
    pub lost_blocks: usize,
}

impl DecodeReport {
    /// No corruption found.
    pub fn is_clean(&self) -> bool {
        self.corrupt_chunks.is_empty()
    }

    /// Chunks that survived.
    pub fn salvaged_chunks(&self) -> usize {
        self.total_chunks - self.corrupt_chunks.len()
    }
}

/// Checksum-only integrity walk over serialized `.czb` bytes: parse the
/// header (v≥4 headers are digest-verified by `parse_header` itself),
/// validate the chunk index, then bounds-check and CRC every chunk
/// payload without inflating anything — reading each compressed byte
/// once is what makes `czb verify` fast enough to run routinely.
///
/// `Err` means the stream is unreadable (bad magic, truncated or
/// digest-corrupt header, inconsistent chunk index); `Ok` with a
/// non-empty [`DecodeReport::corrupt_chunks`] means the header is sound
/// but those payloads are damaged. Files below v4 carry no payload
/// checksums, so for them this only proves the index and bounds are
/// consistent — `czb verify --deep` actually decodes and catches what a
/// missing checksum cannot.
pub fn verify_stream(bytes: &[u8]) -> Result<DecodeReport, String> {
    let (file, _header_len) = CzbFile::parse_header(bytes)?;
    validate_chunk_index(&file)?;
    let mut report = DecodeReport {
        total_chunks: file.chunks.len(),
        ..DecodeReport::default()
    };
    for (i, entry) in file.chunks.iter().enumerate() {
        let r = chunk_payload(bytes, entry).and_then(|p| verify_chunk_crc(&file, p, i));
        if let Err(e) = r {
            report.lost_blocks += entry.nblocks as usize;
            report.corrupt_chunks.push((i, e));
        }
    }
    Ok(report)
}

/// Salvage decompression (serial): decode every intact chunk, zero-fill
/// the blocks of every corrupt one, and report what was lost instead of
/// failing the stream. See [`decompress_field_salvage_core`].
pub fn decompress_field_salvage(
    bytes: &[u8],
    engine: &dyn WaveletEngine,
) -> Result<(Field3, CzbFile, DecodeReport), String> {
    decompress_field_salvage_core(&ScopedExec, bytes, engine, 1)
}

/// Salvage decompression on the given executor: the graceful-degradation
/// counterpart of [`decompress_field_core`]. Chunks decode in parallel
/// exactly like the strict chunk-granular path, but there is no abort
/// flag — a chunk that fails its checksum, inflate or stage-1 decode is
/// zero-filled (all of its blocks, erasing any partially scattered
/// output so corrupt regions are deterministic zeros rather than
/// garbage) and recorded in the [`DecodeReport`], while every other
/// chunk still decodes bit-identically to the strict paths.
///
/// `Err` is reserved for unreadable streams (header/index damage);
/// payload damage always comes back as `Ok` with a populated report.
pub(crate) fn decompress_field_salvage_core(
    exec: &dyn Execute,
    bytes: &[u8],
    engine: &dyn WaveletEngine,
    nthreads: usize,
) -> Result<(Field3, CzbFile, DecodeReport), String> {
    let (file, _header_len) = CzbFile::parse_header(bytes)?;
    validate_chunk_index(&file)?;
    let mut field = Field3::zeros(file.nx as usize, file.ny as usize, file.nz as usize);
    let grid = grid_for(&file, &field)?;
    let stage2 = stage2_of(&file);
    let bs = file.bs as usize;
    let vol = bs * bs * bs;
    let nchunks = file.chunks.len();
    let writer = FieldWriter { ptr: field.data.as_mut_ptr(), len: field.data.len() };
    let queue = SpanQueue::new(nchunks, 1);
    let failures: Mutex<Vec<(usize, String)>> = Mutex::new(Vec::new());
    cluster::run_on(exec, nthreads.max(1).min(nchunks.max(1)), |_| {
        let mut tmp: Vec<u8> = Vec::new();
        let mut raw: Vec<u8> = Vec::new();
        let mut offsets: Vec<(usize, usize)> = Vec::new();
        let mut scratch = Stage1Scratch::default();
        let mut block = vec![0f32; vol];
        let zeros = vec![0f32; vol];
        while let Some(span) = queue.next_span() {
            for cidx in span {
                let entry = file.chunks[cidx];
                let decoded = chunk_payload(bytes, &entry)
                    .and_then(|payload| {
                        decode_chunk_into(
                            &file,
                            stage2,
                            payload,
                            cidx,
                            &mut tmp,
                            &mut raw,
                            &mut offsets,
                        )
                    })
                    .and_then(|()| {
                        for (j, &(off, size)) in offsets.iter().enumerate() {
                            decode_block_payload(
                                &file,
                                &raw[off..off + size],
                                engine,
                                &mut scratch,
                                &mut block,
                            )?;
                            // SAFETY: same disjointness argument as the
                            // strict chunk-parallel path — validated chunk
                            // index, one worker per chunk.
                            unsafe {
                                writer.insert_block(&grid, entry.first_block as usize + j, &block)
                            };
                        }
                        Ok(())
                    });
                if let Err(e) = decoded {
                    // Erase anything the failed chunk partially scattered:
                    // the chunk's blocks are owned by this worker, so the
                    // rewrite races with nobody.
                    for j in 0..entry.nblocks as usize {
                        // SAFETY: as above.
                        unsafe {
                            writer.insert_block(&grid, entry.first_block as usize + j, &zeros)
                        };
                    }
                    failures.lock().unwrap().push((cidx, e));
                }
            }
        }
    });
    let mut corrupt = failures.into_inner().unwrap();
    corrupt.sort_by_key(|&(i, _)| i);
    let lost_blocks = corrupt
        .iter()
        .map(|&(i, _)| file.chunks[i].nblocks as usize)
        .sum();
    let report = DecodeReport { total_chunks: nchunks, corrupt_chunks: corrupt, lost_blocks };
    Ok((field, file, report))
}

/// Chunk-granular parallel decode: every worker owns its inflate/decode
/// buffers (allocation-free steady state) and scatters finished blocks
/// straight into the shared output field — block writes are disjoint
/// because the chunk index tiles the block range (validated) and the
/// queue hands each chunk to exactly one worker. A shared abort flag
/// stops the other workers from draining the rest of the queue once any
/// chunk fails to decode.
fn decompress_chunks_parallel(
    exec: &dyn Execute,
    bytes: &[u8],
    file: &CzbFile,
    grid: &BlockGrid,
    engine: &dyn WaveletEngine,
    nthreads: usize,
    field: &mut Field3,
) -> Result<(), String> {
    let stage2 = stage2_of(file);
    let bs = file.bs as usize;
    let vol = bs * bs * bs;
    let nchunks = file.chunks.len();
    let writer = FieldWriter { ptr: field.data.as_mut_ptr(), len: field.data.len() };
    let queue = SpanQueue::new(nchunks, 1);
    let abort = AtomicBool::new(false);
    let results: Vec<Result<(), String>> =
        cluster::run_on(exec, nthreads.min(nchunks), |_| {
            let r = (|| -> Result<(), String> {
                // worker-owned scratch: warm after the first chunk
                let mut tmp: Vec<u8> = Vec::new();
                let mut raw: Vec<u8> = Vec::new();
                let mut offsets: Vec<(usize, usize)> = Vec::new();
                let mut scratch = Stage1Scratch::default();
                let mut block = vec![0f32; vol];
                while let Some(span) = queue.next_span() {
                    // a sibling hit a corrupt chunk: stop pulling work, its
                    // error is what the caller will see
                    if abort.load(Ordering::Relaxed) {
                        return Ok(());
                    }
                    for cidx in span {
                        let entry = file.chunks[cidx];
                        let payload = chunk_payload(bytes, &entry)?;
                        decode_chunk_into(
                            file,
                            stage2,
                            payload,
                            cidx,
                            &mut tmp,
                            &mut raw,
                            &mut offsets,
                        )?;
                        for (j, &(off, size)) in offsets.iter().enumerate() {
                            decode_block_payload(
                                file,
                                &raw[off..off + size],
                                engine,
                                &mut scratch,
                                &mut block,
                            )?;
                            // SAFETY: validate_chunk_index proved chunks tile
                            // 0..nblocks disjointly and each chunk is pulled by
                            // exactly one worker, so this block id is written
                            // exactly once and lies inside the field buffer.
                            unsafe {
                                writer.insert_block(grid, entry.first_block as usize + j, &block)
                            };
                        }
                    }
                }
                Ok(())
            })();
            if r.is_err() {
                abort.store(true, Ordering::Relaxed);
            }
            r
        });
    for r in results {
        r?;
    }
    Ok(())
}

/// Intra-chunk parallel decode for archives with fewer chunks than
/// workers: per chunk (sequentially), inflate the stage-2 sub-frames
/// concurrently into disjoint slices of the shuffled stream, unshuffle,
/// then stage-1 decode the chunk's blocks concurrently into the field.
/// Unframed legacy chunks (v≤2) inflate serially but still get parallel
/// block decode.
fn decompress_chunks_wide(
    exec: &dyn Execute,
    bytes: &[u8],
    file: &CzbFile,
    grid: &BlockGrid,
    engine: &dyn WaveletEngine,
    nthreads: usize,
    field: &mut Field3,
) -> Result<(), String> {
    let stage2 = stage2_of(file);
    let bs = file.bs as usize;
    let vol = bs * bs * bs;
    let writer = FieldWriter { ptr: field.data.as_mut_ptr(), len: field.data.len() };
    let mut tmp: Vec<u8> = Vec::new();
    let mut raw: Vec<u8> = Vec::new();
    let mut offsets: Vec<(usize, usize)> = Vec::new();
    for (cidx, entry) in file.chunks.iter().enumerate() {
        let payload = chunk_payload(bytes, entry)?;
        check_rawsize(file, entry, cidx)?;
        verify_chunk_crc(file, payload, cidx)?;
        let expect = file.chunk_stage2_len(entry);
        let frames = if file.frame_raw > 0 {
            parse_frame_table(payload, expect, file.frame_raw as usize)
                .map_err(|e| format!("chunk {cidx}: {e}"))?
        } else {
            Vec::new()
        };
        if frames.len() > 1 {
            // parallel stage-2: each frame decodes into its fixed,
            // disjoint slice of the shuffled stream
            let dst = match file.shuffle {
                ShuffleMode::None => &mut raw,
                _ => &mut tmp,
            };
            dst.clear();
            dst.resize(expect, 0);
            let slices = SliceWriter { ptr: dst.as_mut_ptr(), len: dst.len() };
            let queue = SpanQueue::new(frames.len(), 1);
            let frames = &frames;
            let abort = AtomicBool::new(false);
            let results: Vec<Result<(), String>> =
                cluster::run_on(exec, nthreads.min(frames.len()), |_| {
                    let r = (|| -> Result<(), String> {
                        let mut buf: Vec<u8> = Vec::new();
                        while let Some(span) = queue.next_span() {
                            // a sibling hit a corrupt frame: stop draining
                            if abort.load(Ordering::Relaxed) {
                                return Ok(());
                            }
                            for fi in span {
                                let f = &frames[fi];
                                buf.clear();
                                stage2
                                    .decompress_into(
                                        &payload[f.payload.clone()],
                                        f.raw.len(),
                                        &mut buf,
                                    )
                                    .map_err(|e| format!("chunk {cidx} frame {fi}: {e}"))?;
                                if buf.len() != f.raw.len() {
                                    return Err(format!(
                                        "chunk {cidx} frame {fi}: decoded {} bytes, expected {}",
                                        buf.len(),
                                        f.raw.len()
                                    ));
                                }
                                // SAFETY: frame raw spans tile the buffer
                                // disjointly and each frame index is pulled by
                                // exactly one worker.
                                unsafe { slices.write_at(f.raw.start, &buf) };
                            }
                        }
                        Ok(())
                    })();
                    if r.is_err() {
                        abort.store(true, Ordering::Relaxed);
                    }
                    r
                });
            for r in results {
                r?;
            }
            match file.shuffle {
                ShuffleMode::None => {}
                ShuffleMode::Byte4 => shuffle::byte_unshuffle_into(&tmp, 4, &mut raw),
                ShuffleMode::Bit4 => {
                    let rawsize = entry.rawsize as usize;
                    if tmp.len() != shuffle::bit_shuffled_len(rawsize, 4) {
                        return Err(format!(
                            "chunk {cidx}: bit-shuffled size {} inconsistent with raw size {rawsize}",
                            tmp.len()
                        ));
                    }
                    shuffle::bit_unshuffle_into(&tmp, 4, rawsize / 4, &mut raw);
                }
            }
            if raw.len() != entry.rawsize as usize {
                return Err(format!(
                    "chunk {cidx}: raw size {} != index {}",
                    raw.len(),
                    entry.rawsize
                ));
            }
            walk_block_prefixes(&raw, entry.nblocks, &mut offsets)?;
        } else {
            decode_chunk_into(file, stage2, payload, cidx, &mut tmp, &mut raw, &mut offsets)?;
        }

        // parallel stage 1: the chunk's blocks decode concurrently and
        // scatter into disjoint field regions
        let nb = offsets.len();
        if nb == 0 {
            continue;
        }
        let queue = SpanQueue::new(nb, nb.div_ceil(4 * nthreads).max(1));
        let raw_ref = &raw;
        let offsets_ref = &offsets;
        let abort = AtomicBool::new(false);
        let results: Vec<Result<(), String>> =
            cluster::run_on(exec, nthreads.min(nb), |_| {
                let r = (|| -> Result<(), String> {
                    let mut scratch = Stage1Scratch::default();
                    let mut block = vec![0f32; vol];
                    while let Some(span) = queue.next_span() {
                        // a sibling hit a corrupt block: stop draining
                        if abort.load(Ordering::Relaxed) {
                            return Ok(());
                        }
                        for j in span {
                            let (off, size) = offsets_ref[j];
                            decode_block_payload(
                                file,
                                &raw_ref[off..off + size],
                                engine,
                                &mut scratch,
                                &mut block,
                            )?;
                            // SAFETY: block ids within the chunk are disjoint
                            // across workers (queue) and the chunk index tiles
                            // the block range (validated by the caller).
                            unsafe {
                                writer.insert_block(grid, entry.first_block as usize + j, &block)
                            };
                        }
                    }
                    Ok(())
                })();
                if r.is_err() {
                    abort.store(true, Ordering::Relaxed);
                }
                r
            });
        for r in results {
            r?;
        }
    }
    Ok(())
}

/// One `.czb` section of a multi-section decode ([`decompress_sections`]):
/// how to get its bytes — invoked lazily by the first worker to arrive,
/// so archive section I/O overlaps sibling decode — and the shared-cache
/// identity its decoded chunks are filed under.
pub(crate) struct SectionJob<'a> {
    pub(crate) load: Box<dyn Fn() -> Result<&'a [u8], String> + Sync + 'a>,
    pub(crate) cache: Arc<ChunkCache>,
    pub(crate) stream: StreamId,
}

/// A section a worker has opened: parsed header, validated chunk index,
/// output field allocated (parked in the matching [`QuantState`]) and a
/// chunk queue every worker can steal spans from.
struct OpenedSection<'a> {
    file: CzbFile,
    grid: BlockGrid,
    payload: &'a [u8],
    queue: SpanQueue,
    writer: FieldWriter,
    stage2: &'static dyn Stage2Codec,
}

/// Per-section shared state of a multi-section decode.
struct QuantState<'a> {
    /// Opened exactly once by the first worker to arrive (the lazy
    /// section load, header parse and output allocation happen inside).
    opened: OnceLock<Result<OpenedSection<'a>, String>>,
    /// Output field parked by the opener while workers scatter blocks
    /// into it through the raw [`FieldWriter`].
    out: Mutex<Option<Field3>>,
    /// First chunk-decode error; `failed` stops siblings from pulling
    /// more of this section's spans (other sections are unaffected).
    error: Mutex<Option<String>>,
    failed: AtomicBool,
}

impl<'a> QuantState<'a> {
    fn new() -> Self {
        Self {
            opened: OnceLock::new(),
            out: Mutex::new(None),
            error: Mutex::new(None),
            failed: AtomicBool::new(false),
        }
    }

    fn fail(&self, e: String) {
        let mut slot = self.error.lock().unwrap();
        if slot.is_none() {
            *slot = Some(e);
        }
        self.failed.store(true, Ordering::Relaxed);
    }
}

fn open_section<'a>(
    job: &SectionJob<'a>,
    st: &QuantState<'a>,
) -> Result<OpenedSection<'a>, String> {
    let payload = (job.load)()?;
    let (file, _header_len) = CzbFile::parse_header(payload)?;
    validate_chunk_index(&file)?;
    let mut field = Field3::zeros(file.nx as usize, file.ny as usize, file.nz as usize);
    let grid = grid_for(&file, &field)?;
    let writer = FieldWriter { ptr: field.data.as_mut_ptr(), len: field.data.len() };
    let queue = SpanQueue::new(file.chunks.len(), 1);
    let stage2 = stage2_of(&file);
    // the Vec's heap buffer (what `writer` points into) is unaffected by
    // moving the Field3 into the mutex
    *st.out.lock().unwrap() = Some(field);
    Ok(OpenedSection { file, grid, payload, queue, writer, stage2 })
}

/// Decode chunk `cidx` of an opened section into its shared output
/// field, through the shared chunk cache: a hit skips the stage-2
/// inflate entirely, a miss decodes into recycled buffers and leaves the
/// decoded chunk behind for random-access readers over the same stream.
fn decode_section_chunk(
    o: &OpenedSection,
    cache: &ChunkCache,
    stream: StreamId,
    cidx: usize,
    engine: &dyn WaveletEngine,
    tmp: &mut Vec<u8>,
    spare: &mut Option<(Vec<u8>, Vec<(usize, usize)>)>,
    scratch: &mut Stage1Scratch,
    block: &mut [f32],
) -> Result<(), String> {
    let entry = o.file.chunks[cidx];
    let decoded = match cache.get(stream, cidx as u32) {
        Some(c) => c,
        None => {
            let payload = chunk_payload(o.payload, &entry)?;
            let (mut raw, mut offsets) = spare.take().unwrap_or_default();
            if let Err(e) =
                decode_chunk_into(&o.file, o.stage2, payload, cidx, tmp, &mut raw, &mut offsets)
            {
                *spare = Some((raw, offsets));
                return Err(e);
            }
            let decoded = Arc::new(DecodedChunk {
                raw,
                block_offsets: offsets,
                first_block: entry.first_block,
            });
            if let Some(bufs) = cache.insert(stream, cidx as u32, decoded.clone()) {
                *spare = Some(bufs);
            }
            decoded
        }
    };
    // a cached chunk under this stream id must describe these bytes; the
    // raw scatter below relies on the shape, so check it regardless
    if decoded.first_block != entry.first_block
        || decoded.block_offsets.len() != entry.nblocks as usize
    {
        return Err(format!("chunk {cidx}: cached chunk shape mismatch"));
    }
    for (j, &(off, size)) in decoded.block_offsets.iter().enumerate() {
        decode_block_payload(&o.file, &decoded.raw[off..off + size], engine, scratch, block)?;
        // SAFETY: validate_chunk_index proved the chunk index tiles
        // 0..nblocks disjointly and the section queue hands each chunk
        // to exactly one worker, so this block id is written exactly
        // once and lies inside the field buffer.
        unsafe { o.writer.insert_block(&o.grid, entry.first_block as usize + j, block) };
    }
    Ok(())
}

/// Decode many independent `.czb` sections concurrently on one executor
/// with cross-section overlap (the `.czs` multi-quantity read path; see
/// the module docs). Returns one result per job, in job order; a failed
/// section does not stop its siblings. Bit-identical to decoding each
/// section alone at any thread count.
pub(crate) fn decompress_sections(
    exec: &dyn Execute,
    jobs: &[SectionJob<'_>],
    engine: &dyn WaveletEngine,
    nthreads: usize,
) -> Vec<Result<(Field3, CzbFile), String>> {
    let states: Vec<QuantState> = jobs.iter().map(|_| QuantState::new()).collect();
    let nthreads = nthreads.max(1);
    let njobs = jobs.len();
    cluster::run_on(exec, nthreads, |t| {
        // worker-owned scratch, shared across every section it touches
        let mut tmp: Vec<u8> = Vec::new();
        let mut spare: Option<(Vec<u8>, Vec<(usize, usize)>)> = None;
        let mut scratch = Stage1Scratch::default();
        let mut block: Vec<f32> = Vec::new();
        // staggered sweep start: worker t begins at section t, so up to
        // njobs section loads + opens are in flight at once instead of
        // every worker queueing behind section 0's OnceLock; each worker
        // still visits every section, so all queues drain before return
        for k in 0..njobs {
            let qi = (k + t) % njobs;
            let (job, st) = (&jobs[qi], &states[qi]);
            let Ok(o) = st.opened.get_or_init(|| open_section(job, st)) else {
                continue;
            };
            let bs = o.file.bs as usize;
            block.clear();
            block.resize(bs * bs * bs, 0.0);
            while let Some(span) = o.queue.next_span() {
                // a sibling hit a corrupt chunk in this section: stop
                // pulling its work, move on to the next section
                if st.failed.load(Ordering::Relaxed) {
                    break;
                }
                for cidx in span {
                    if let Err(e) = decode_section_chunk(
                        o,
                        &job.cache,
                        job.stream,
                        cidx,
                        engine,
                        &mut tmp,
                        &mut spare,
                        &mut scratch,
                        &mut block,
                    ) {
                        st.fail(e);
                        break;
                    }
                }
            }
        }
    });
    states
        .iter()
        .map(|st| match st.opened.get() {
            // unreachable in practice: every worker sweeps every section
            None => Err("section was never opened".to_string()),
            Some(Err(e)) => Err(e.clone()),
            Some(Ok(o)) => {
                if st.failed.load(Ordering::Relaxed) {
                    Err(st
                        .error
                        .lock()
                        .unwrap()
                        .take()
                        .unwrap_or_else(|| "section decode failed".to_string()))
                } else {
                    let field = st
                        .out
                        .lock()
                        .unwrap()
                        .take()
                        .expect("opened section parked its output field");
                    Ok((field, o.file.clone()))
                }
            }
        })
        .collect()
}

/// The absolute stage-1 parameter this file was encoded with.
pub fn file_eps_abs(file: &CzbFile) -> f32 {
    eps_abs_of(&file.stage1, file.global_range())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codec::Codec;
    use crate::metrics::psnr;
    use crate::pipeline::compressor::{compress_field, NativeEngine, PipelineConfig};
    use crate::pipeline::format::{CoeffCodec, Stage1};
    use crate::util::prng::Pcg32;
    use crate::wavelet::WaveletKind;

    fn smooth_field(n: usize, seed: u64) -> Field3 {
        let mut rng = Pcg32::new(seed);
        Field3::from_vec(n, n, n, crate::util::prop::gen_smooth_field(&mut rng, n))
    }

    fn bits_equal(a: &Field3, b: &Field3) -> bool {
        a.data.len() == b.data.len()
            && a.data.iter().zip(&b.data).all(|(x, y)| x.to_bits() == y.to_bits())
    }

    #[test]
    fn roundtrip_wavelet_psnr_scales_with_eps() {
        let f = smooth_field(64, 10);
        let mut prev_psnr = 0.0f64;
        for eps in [1e-2f32, 1e-3, 1e-4] {
            let cfg = PipelineConfig::paper_default(eps);
            let (bytes, _) = compress_field(&f, "p", &cfg, &NativeEngine);
            let (back, _) = decompress_field(&bytes, &NativeEngine).unwrap();
            let p = psnr(&f.data, &back.data).unwrap();
            // tighter epsilon -> higher PSNR
            assert!(p > prev_psnr - 1.0, "eps {eps}: psnr {p} prev {prev_psnr}");
            assert!(p > 40.0, "eps {eps}: psnr {p}");
            prev_psnr = p;
        }
    }

    #[test]
    fn roundtrip_copy_is_bit_exact() {
        let f = smooth_field(32, 11);
        let cfg = PipelineConfig::new(16, Stage1::Copy, Codec::ZlibDef);
        let (bytes, st) = compress_field(&f, "rho", &cfg, &NativeEngine);
        let (back, file) = decompress_field(&bytes, &NativeEngine).unwrap();
        assert_eq!(back.data, f.data);
        assert_eq!(file.name, "rho");
        assert!(st.ratio() > 0.5);
    }

    #[test]
    fn roundtrip_all_lossy_schemes_bounded_error() {
        let f = smooth_field(32, 12);
        let range = {
            let (lo, hi) = f.range();
            hi - lo
        };
        for (stage1, bound_factor) in [
            (Stage1::Zfp { tol_rel: 1e-3 }, 1.0),
            (Stage1::Sz { eb_rel: 1e-3 }, 1.0),
            (
                Stage1::Wavelet {
                    kind: WaveletKind::Avg3,
                    eps_rel: 1e-3,
                    zbits: 0,
                    coeff: CoeffCodec::None,
                },
                60.0,
            ),
        ] {
            let cfg = PipelineConfig::new(32, stage1, Codec::ZlibDef);
            let (bytes, _) = compress_field(&f, "e", &cfg, &NativeEngine);
            let (back, _) = decompress_field(&bytes, &NativeEngine).unwrap();
            let maxerr = f
                .data
                .iter()
                .zip(&back.data)
                .map(|(a, b)| (a - b).abs())
                .fold(0f32, f32::max);
            let bound = 1e-3 * range * bound_factor;
            assert!(maxerr <= bound, "{stage1:?}: err {maxerr} bound {bound}");
        }
    }

    #[test]
    fn random_access_matches_full_decode() {
        let f = smooth_field(64, 13);
        let mut cfg = PipelineConfig::paper_default(1e-3);
        cfg.chunk_bytes = 8 << 10; // many chunks
        let (bytes, st) = compress_field(&f, "p", &cfg, &NativeEngine);
        assert!(st.nchunks >= 2);
        let (full, file) = decompress_field(&bytes, &NativeEngine).unwrap();
        let engine = NativeEngine;
        let mut reader = BlockReader::new(&bytes, &engine).unwrap().with_cache_capacity(2);
        let bs = file.bs as usize;
        let grid = crate::core::block::BlockGrid::new(&f, bs);
        let mut blk = vec![0f32; bs * bs * bs];
        let mut expected = crate::core::block::Block::zeros(bs);
        // access in a scattered order to exercise the cache (and its
        // buffer recycling on eviction)
        let order: Vec<u32> = (0..file.nblocks).rev().chain(0..file.nblocks).collect();
        for id in order {
            reader.read_block(id, &mut blk).unwrap();
            grid.extract(&full, id as usize, &mut expected);
            assert_eq!(blk, expected.data, "block {id}");
        }
        assert!(reader.cache_hits > 0);
        assert!(reader.cache_misses > 2, "eviction path must have run");
    }

    #[test]
    fn shared_cache_readers_agree_and_share_decodes() {
        // two readers over the same quantity, one shared cache + stream:
        // the second reader's first access must be a cache hit
        let f = smooth_field(64, 19);
        let mut cfg = PipelineConfig::paper_default(1e-3);
        cfg.chunk_bytes = 32 << 10;
        let (bytes, st) = compress_field(&f, "p", &cfg, &NativeEngine);
        assert!(st.nchunks >= 2);
        let engine = NativeEngine;
        let cache = Arc::new(ChunkCache::new(16));
        let stream = cache.register_stream();
        let mut r1 = BlockReader::new(&bytes, &engine)
            .unwrap()
            .with_shared_cache(cache.clone(), stream);
        let mut r2 = BlockReader::new(&bytes, &engine)
            .unwrap()
            .with_shared_cache(cache.clone(), stream);
        let bs = r1.file.bs as usize;
        let mut a = vec![0f32; bs * bs * bs];
        let mut b = vec![0f32; bs * bs * bs];
        r1.read_block(0, &mut a).unwrap();
        r2.read_block(0, &mut b).unwrap();
        assert_eq!(a, b);
        assert_eq!(r1.cache_misses, 1);
        assert_eq!(r2.cache_hits, 1, "second reader must reuse the shared decode");
        assert_eq!(r2.cache_misses, 0);
        assert!(cache.hits() >= 1);
    }

    #[test]
    fn concurrent_shared_cache_readers_decode_correctly() {
        // several threads hammer one shared cache over the same archive;
        // every block must come back identical to the serial decode
        let f = smooth_field(64, 23);
        let mut cfg = PipelineConfig::paper_default(1e-3);
        cfg.chunk_bytes = 16 << 10;
        let (bytes, st) = compress_field(&f, "p", &cfg, &NativeEngine);
        assert!(st.nchunks >= 4);
        let (full, file) = decompress_field(&bytes, &NativeEngine).unwrap();
        let engine = NativeEngine;
        let cache = Arc::new(ChunkCache::new(4)); // small: force churn
        let stream = cache.register_stream();
        let bs = file.bs as usize;
        let grid = crate::core::block::BlockGrid::new(&f, bs);
        std::thread::scope(|s| {
            for t in 0..4u64 {
                let cache = cache.clone();
                let bytes = &bytes;
                let engine = &engine;
                let full = &full;
                let grid = &grid;
                s.spawn(move || {
                    let mut reader = BlockReader::new(bytes, engine)
                        .unwrap()
                        .with_shared_cache(cache, stream);
                    let mut blk = vec![0f32; bs * bs * bs];
                    let mut expected = crate::core::block::Block::zeros(bs);
                    let mut rng = Pcg32::new(0x1234 + t);
                    for _ in 0..60 {
                        let id = rng.below(reader.file.nblocks);
                        reader.read_block(id, &mut blk).unwrap();
                        grid.extract(full, id as usize, &mut expected);
                        assert_eq!(blk, expected.data, "block {id}");
                    }
                });
            }
        });
        assert!(cache.hits() + cache.misses() >= 240);
    }

    #[test]
    fn parallel_whole_field_decode_matches_serial() {
        let f = smooth_field(96, 31); // 27 blocks at bs=32
        let mut cfg = PipelineConfig::paper_default(1e-3);
        cfg.chunk_bytes = 256 << 10; // 2-block spans -> 14 chunks
        cfg.nthreads = 4;
        let (bytes, st) = compress_field(&f, "p", &cfg, &NativeEngine);
        assert!(st.nchunks >= 4, "nchunks {}", st.nchunks);
        let (serial, _) = decompress_field(&bytes, &NativeEngine).unwrap();
        for nthreads in [2usize, 4, 8] {
            let (par, file) = decompress_field_mt(&bytes, &NativeEngine, nthreads).unwrap();
            assert_eq!(file.nblocks as usize, st.nblocks);
            assert!(bits_equal(&serial, &par), "nthreads {nthreads}");
        }
    }

    #[test]
    fn single_chunk_archive_decodes_in_parallel_bit_exact() {
        // the wide path: one chunk, many sub-frames — stage-2 inflate and
        // stage-1 decode must fan out and still match serial bit-for-bit
        let f = smooth_field(64, 66);
        let mut cfg = PipelineConfig::paper_default(1e-3);
        cfg.chunk_bytes = 64 << 20; // everything in one chunk
        cfg.frame_bytes = 2 << 10; // many frames inside it
        for stage2 in [Codec::ZlibBest, Codec::Lz4, Codec::None] {
            cfg.stage2 = stage2;
            let (bytes, st) = compress_field(&f, "p", &cfg, &NativeEngine);
            assert_eq!(st.nchunks, 1, "{stage2:?}");
            let (file, _) = CzbFile::parse_header(&bytes).unwrap();
            assert!(file.frame_raw > 0);
            let (serial, _) = decompress_field(&bytes, &NativeEngine).unwrap();
            for nthreads in [2usize, 4, 8] {
                let (par, _) = decompress_field_mt(&bytes, &NativeEngine, nthreads).unwrap();
                assert!(bits_equal(&serial, &par), "{stage2:?} nthreads {nthreads}");
            }
        }
    }

    #[test]
    fn legacy_v1_archives_decode_bit_exact() {
        // repack a v3 archive's chunks as monolithic legacy streams under
        // a v1 header: exactly what a pre-framing writer produced. Every
        // decode path must accept it and reproduce the same field.
        let f = smooth_field(64, 55);
        let mut cfg = PipelineConfig::paper_default(1e-3);
        cfg.chunk_bytes = 64 << 10;
        cfg.frame_bytes = 4 << 10;
        let (v3, st) = compress_field(&f, "p", &cfg, &NativeEngine);
        assert!(st.nchunks > 1);
        let (file, _) = CzbFile::parse_header(&v3).unwrap();
        let codec = file.stage2.codec();
        let mut payloads: Vec<Vec<u8>> = Vec::new();
        for entry in &file.chunks {
            let payload = &v3[entry.offset as usize..][..entry.csize as usize];
            let expect = file.chunk_stage2_len(entry);
            let mut shuffled = Vec::new();
            decompress_framed(codec, payload, expect, file.frame_raw as usize, &mut shuffled)
                .unwrap();
            let mut legacy = Vec::new();
            codec.compress_into(&shuffled, &mut legacy);
            payloads.push(legacy);
        }
        let mut v1 = file.clone();
        v1.version = 1;
        v1.frame_raw = 0;
        let hsize = CzbFile::header_size_for(1, v1.name.len(), v1.chunks.len());
        let mut offset = hsize as u64;
        for (c, p) in v1.chunks.iter_mut().zip(&payloads) {
            c.offset = offset;
            c.csize = p.len() as u32;
            offset += p.len() as u64;
        }
        let mut v1_bytes = Vec::new();
        v1.write_header(&mut v1_bytes);
        assert_eq!(v1_bytes.len(), hsize);
        for p in &payloads {
            v1_bytes.extend_from_slice(p);
        }
        let (a, _) = decompress_field(&v3, &NativeEngine).unwrap();
        let (b, fb) = decompress_field(&v1_bytes, &NativeEngine).unwrap();
        assert_eq!(fb.version, 1);
        assert_eq!(fb.frame_raw, 0);
        assert!(bits_equal(&a, &b), "legacy serial decode must match");
        for nthreads in [2usize, 8, 16] {
            let (c, _) = decompress_field_mt(&v1_bytes, &NativeEngine, nthreads).unwrap();
            assert!(bits_equal(&a, &c), "legacy parallel decode (t={nthreads})");
        }
        // random access into the legacy archive
        let engine = NativeEngine;
        let mut reader = BlockReader::new(&v1_bytes, &engine).unwrap();
        let bs = fb.bs as usize;
        let mut blk = vec![0f32; bs * bs * bs];
        reader.read_block(0, &mut blk).unwrap();
        let grid = crate::core::block::BlockGrid::new(&a, bs);
        let mut expected = crate::core::block::Block::zeros(bs);
        grid.extract(&a, 0, &mut expected);
        assert_eq!(blk, expected.data);
    }

    #[test]
    fn coeff_codecs_do_not_change_psnr() {
        // paper Table 2: "The PSNR value is determined by the first
        // substage and is unaffected by the subsequent lossless techniques"
        let f = smooth_field(32, 14);
        let mut psnrs = Vec::new();
        for coeff in [CoeffCodec::None, CoeffCodec::Fpzip, CoeffCodec::Spdp] {
            let stage1 = Stage1::Wavelet {
                kind: WaveletKind::Avg3,
                eps_rel: 1e-3,
                zbits: 0,
                coeff,
            };
            let cfg = PipelineConfig::new(32, stage1, Codec::ZlibDef);
            let (bytes, _) = compress_field(&f, "p", &cfg, &NativeEngine);
            let (back, _) = decompress_field(&bytes, &NativeEngine).unwrap();
            psnrs.push(psnr(&f.data, &back.data).unwrap());
        }
        for w in psnrs.windows(2) {
            assert!((w[0] - w[1]).abs() < 0.6, "psnrs {psnrs:?}");
        }
    }

    #[test]
    fn bit4_shuffle_roundtrips_and_changes_the_stream() {
        // Bit4 is a lossless chunk preconditioner: the decompressed field
        // must be bit-identical to the Byte4 archive's, while the stage-2
        // input (and usually the stream size) differs
        let f = smooth_field(64, 77);
        let mut cfg = PipelineConfig::paper_default(1e-3);
        cfg.chunk_bytes = 64 << 10; // several chunks
        let (b_byte, _) = compress_field(&f, "p", &cfg.with_shuffle(ShuffleMode::Byte4), &NativeEngine);
        let (b_bit, st) = compress_field(&f, "p", &cfg.with_shuffle(ShuffleMode::Bit4), &NativeEngine);
        assert!(st.nchunks > 1);
        assert_ne!(b_byte, b_bit, "shuffle mode must reach the stream");
        let (file_bit, _) = CzbFile::parse_header(&b_bit).unwrap();
        assert_eq!(file_bit.shuffle, ShuffleMode::Bit4);
        let (d_byte, _) = decompress_field(&b_byte, &NativeEngine).unwrap();
        let (d_bit, _) = decompress_field(&b_bit, &NativeEngine).unwrap();
        assert!(bits_equal(&d_byte, &d_bit));
        // parallel decode handles Bit4 too — the chunk-parallel path...
        let (d_mt, _) = decompress_field_mt(&b_bit, &NativeEngine, 4).unwrap();
        assert!(bits_equal(&d_bit, &d_mt));
        // ...and the intra-chunk wide path (sub-frames smaller than the
        // chunk streams + more threads than chunks), where the Bit4
        // plane-padding arithmetic also shapes the frame spans
        let mut cfg_framed = cfg.with_shuffle(ShuffleMode::Bit4);
        cfg_framed.frame_bytes = 2 << 10;
        let (b_framed, _) = compress_field(&f, "p", &cfg_framed, &NativeEngine);
        let (d_wide, _) = decompress_field_mt(&b_framed, &NativeEngine, 64).unwrap();
        assert!(bits_equal(&d_bit, &d_wide));
    }

    #[test]
    fn parallel_decode_aborts_on_corrupt_chunk() {
        let f = smooth_field(96, 41);
        let mut cfg = PipelineConfig::paper_default(1e-3);
        cfg.chunk_bytes = 128 << 10; // many chunks so the flag matters
        let (bytes, st) = compress_field(&f, "p", &cfg, &NativeEngine);
        assert!(st.nchunks >= 4);
        let (file, _) = CzbFile::parse_header(&bytes).unwrap();
        // truncate-corrupt the first chunk's payload so its stage-2
        // decode (or raw-size check) fails deterministically
        let mut bad = bytes.clone();
        let lo = file.chunks[0].offset as usize;
        let hi = lo + file.chunks[0].csize as usize;
        for b in &mut bad[lo..hi] {
            *b = 0xAB;
        }
        for nthreads in [2usize, 4, 8] {
            assert!(
                decompress_field_mt(&bad, &NativeEngine, nthreads).is_err(),
                "nthreads {nthreads}"
            );
        }
    }

    #[test]
    fn corrupt_frame_tables_error_for_every_codec() {
        // satellite: every registered codec must reject fuzzed frame
        // tables and truncated payloads — error, never panic or OOM — in
        // the serial, chunk-parallel and wide decode paths alike
        let f = smooth_field(32, 67);
        for stage2 in Codec::ALL {
            let mut cfg = PipelineConfig::new(16, Stage1::Copy, stage2);
            cfg.chunk_bytes = 32 << 10;
            cfg.frame_bytes = 4 << 10;
            let (bytes, st) = compress_field(&f, "p", &cfg, &NativeEngine);
            assert!(st.nchunks >= 2, "{stage2:?}: nchunks {}", st.nchunks);
            let (file, _) = CzbFile::parse_header(&bytes).unwrap();
            let mut bad = bytes.clone();
            let lo = file.chunks[0].offset as usize;
            bad[lo..lo + 4].copy_from_slice(&u32::MAX.to_le_bytes());
            assert!(decompress_field(&bad, &NativeEngine).is_err(), "{stage2:?} serial");
            for nthreads in [2usize, 16] {
                assert!(
                    decompress_field_mt(&bad, &NativeEngine, nthreads).is_err(),
                    "{stage2:?} nthreads {nthreads}"
                );
            }
            // truncated archive
            assert!(decompress_field(&bytes[..bytes.len() - 3], &NativeEngine).is_err());
            assert!(decompress_field_mt(&bytes[..bytes.len() - 3], &NativeEngine, 4).is_err());
        }
    }

    #[test]
    fn crafted_huge_rawsize_is_rejected_before_allocating() {
        // a chunk-index entry claiming a 4 GiB raw stream on a tiny
        // payload must be refused by the plausibility bound, not
        // reserved for
        let f = smooth_field(32, 71);
        let cfg = PipelineConfig::paper_default(1e-3);
        let (bytes, _) = compress_field(&f, "p", &cfg, &NativeEngine);
        let (file, _) = CzbFile::parse_header(&bytes).unwrap();
        // rawsize sits 12 bytes into chunk 0's 24-byte index entry; the
        // v5 header ends with nchunks CRCs, the bound + per-chunk
        // quality column, and the header digest
        let hsize = CzbFile::header_size(file.name.len(), file.chunks.len());
        let entry0 = hsize
            - file.chunks.len() * 24
            - file.chunks.len() * 4
            - (9 + file.chunks.len() * 12)
            - 4;
        let mut bad = bytes.clone();
        bad[entry0 + 12..entry0 + 16].copy_from_slice(&u32::MAX.to_le_bytes());
        // re-seal the header digest so the plausibility bound (not the
        // digest check) is what rejects the crafted entry
        let fixed = crate::util::crc32c::crc32c(&bad[..hsize - 4]);
        bad[hsize - 4..hsize].copy_from_slice(&fixed.to_le_bytes());
        let err = decompress_field(&bad, &NativeEngine).unwrap_err();
        assert!(err.contains("plausible bound"), "{err}");
        assert!(decompress_field_mt(&bad, &NativeEngine, 4).is_err());
        assert!(decompress_field_mt(&bad, &NativeEngine, 64).is_err());
    }

    #[test]
    fn corrupted_payload_is_graceful() {
        let f = smooth_field(32, 15);
        let cfg = PipelineConfig::paper_default(1e-3);
        let (bytes, _) = compress_field(&f, "p", &cfg, &NativeEngine);
        let (czb, hlen) = CzbFile::parse_header(&bytes).unwrap();
        let _ = czb;
        let mut bad = bytes.clone();
        for i in (hlen + 2..bad.len()).step_by(97) {
            bad[i] ^= 0xff;
        }
        // must not panic; error or wrong data both acceptable
        let _ = decompress_field(&bad, &NativeEngine);
        let _ = decompress_field_mt(&bad, &NativeEngine, 4);
        // truncated payload must error, in both paths
        assert!(decompress_field(&bytes[..bytes.len() - 10], &NativeEngine).is_err());
        assert!(decompress_field_mt(&bytes[..bytes.len() - 10], &NativeEngine, 4).is_err());
    }

    /// Compress with several chunks and return (bytes, parsed header,
    /// header length) for the corruption tests.
    fn chunked_archive(seed: u64) -> (Vec<u8>, CzbFile, usize) {
        let f = smooth_field(64, seed);
        let mut cfg = PipelineConfig::paper_default(1e-3);
        cfg.chunk_bytes = 64 << 10;
        let (bytes, st) = compress_field(&f, "p", &cfg, &NativeEngine);
        assert!(st.nchunks > 2, "want several chunks, got {}", st.nchunks);
        let (file, hlen) = CzbFile::parse_header(&bytes).unwrap();
        (bytes, file, hlen)
    }

    #[test]
    fn flipped_payload_bit_is_a_checksum_mismatch_in_every_path() {
        let (bytes, file, _) = chunked_archive(81);
        let target = 1usize; // corrupt chunk 1, leave its neighbors alone
        let entry = file.chunks[target];
        let mut bad = bytes.clone();
        bad[entry.offset as usize + entry.csize as usize / 2] ^= 0x01;
        let err = decompress_field(&bad, &NativeEngine).unwrap_err();
        assert!(err.contains("checksum mismatch"), "{err}");
        for nthreads in [2usize, 4, 8] {
            let err = decompress_field_mt(&bad, &NativeEngine, nthreads).unwrap_err();
            assert!(err.contains("checksum mismatch"), "t={nthreads}: {err}");
        }
    }

    #[test]
    fn verify_stream_walks_without_decoding() {
        let (bytes, file, _) = chunked_archive(82);
        let clean = verify_stream(&bytes).unwrap();
        assert!(clean.is_clean());
        assert_eq!(clean.total_chunks, file.chunks.len());
        assert_eq!(clean.lost_blocks, 0);
        // flip one payload bit: exactly that chunk is reported
        let target = file.chunks.len() - 1;
        let entry = file.chunks[target];
        let mut bad = bytes.clone();
        bad[entry.offset as usize] ^= 0x80;
        let r = verify_stream(&bad).unwrap();
        assert_eq!(r.corrupt_chunks.len(), 1);
        assert_eq!(r.corrupt_chunks[0].0, target);
        assert!(r.corrupt_chunks[0].1.contains("checksum mismatch"));
        assert_eq!(r.lost_blocks, entry.nblocks as usize);
        assert_eq!(r.salvaged_chunks(), file.chunks.len() - 1);
        // a flipped header bit makes the stream unreadable, not corrupt
        let mut worse = bytes.clone();
        worse[7] ^= 0x01;
        let err = verify_stream(&worse).unwrap_err();
        assert!(err.contains("digest mismatch"), "{err}");
    }

    #[test]
    fn salvage_decodes_around_a_corrupt_chunk() {
        let (bytes, file, _) = chunked_archive(83);
        let (clean_field, _) = decompress_field(&bytes, &NativeEngine).unwrap();
        // clean salvage is bit-identical to the strict decode
        let (s, _, rep) = decompress_field_salvage(&bytes, &NativeEngine).unwrap();
        assert!(rep.is_clean());
        assert!(bits_equal(&s, &clean_field));
        // corrupt one mid-archive chunk
        let target = file.chunks.len() / 2;
        let entry = file.chunks[target];
        let mut bad = bytes.clone();
        bad[entry.offset as usize + 3] ^= 0x40;
        let grid = crate::core::block::BlockGrid::new(&clean_field, file.bs as usize);
        let lost: std::ops::Range<usize> = entry.first_block as usize
            ..entry.first_block as usize + entry.nblocks as usize;
        for nthreads in [1usize, 2, 4, 8] {
            let (field, _, rep) =
                decompress_field_salvage_core(&ScopedExec, &bad, &NativeEngine, nthreads)
                    .unwrap();
            assert_eq!(rep.total_chunks, file.chunks.len(), "t={nthreads}");
            assert_eq!(rep.corrupt_chunks.len(), 1, "t={nthreads}");
            assert_eq!(rep.corrupt_chunks[0].0, target, "t={nthreads}");
            assert_eq!(rep.lost_blocks, entry.nblocks as usize, "t={nthreads}");
            // every surviving block is bit-identical to the clean decode,
            // every lost block is exactly zero
            let bs = file.bs as usize;
            let mut got = crate::core::block::Block::zeros(bs);
            let mut want = crate::core::block::Block::zeros(bs);
            for id in 0..file.nblocks as usize {
                grid.extract(&field, id, &mut got);
                if lost.contains(&id) {
                    assert!(got.data.iter().all(|&v| v == 0.0), "t={nthreads} block {id}");
                } else {
                    grid.extract(&clean_field, id, &mut want);
                    assert_eq!(got.data, want.data, "t={nthreads} block {id}");
                }
            }
        }
        // strict decode refuses the same bytes
        assert!(decompress_field(&bad, &NativeEngine).is_err());
    }

    #[test]
    fn salvage_never_errors_on_payload_damage() {
        // smash every payload: the stream stays readable, so salvage must
        // return a full report rather than an error — and never panic
        let (bytes, file, hlen) = chunked_archive(84);
        let mut bad = bytes.clone();
        for b in bad[hlen..].iter_mut() {
            *b = 0xAB;
        }
        for nthreads in [1usize, 4, 8] {
            let (field, _, rep) =
                decompress_field_salvage_core(&ScopedExec, &bad, &NativeEngine, nthreads)
                    .unwrap();
            assert_eq!(rep.corrupt_chunks.len(), file.chunks.len(), "t={nthreads}");
            assert_eq!(rep.lost_blocks, file.nblocks as usize, "t={nthreads}");
            assert_eq!(rep.salvaged_chunks(), 0);
            assert!(field.data.iter().all(|&v| v == 0.0), "t={nthreads}");
            // indices come back sorted and unique
            for w in rep.corrupt_chunks.windows(2) {
                assert!(w[0].0 < w[1].0);
            }
        }
        // header damage is still a hard error for salvage
        let mut worse = bytes.clone();
        worse[9] ^= 0x02;
        assert!(decompress_field_salvage(&worse, &NativeEngine).is_err());
    }
}
