//! Substage-1 + substage-2 compression of a whole field (paper Fig. 1).
//!
//! Node-layer behaviour: worker threads pull contiguous spans of blocks
//! off a shared atomic work queue ([`crate::cluster::SpanQueue`]) —
//! dynamic chunk-granular scheduling instead of one static range per
//! thread, so a straggler can no longer serialize the tail of the field.
//! Each span holds ~`chunk_bytes` worth of raw blocks; the worker runs
//! stage 1 (transform + ε-encode) block by block into its private buffer
//! and stage 2 (shuffle + lossless codec) over each filled buffer,
//! emitting one chunk per span (plus deterministic mid-span seals if the
//! encoded stream outgrows the budget).
//!
//! Two invariants the scheduler maintains:
//! * **Determinism** — span boundaries are fixed by block-id arithmetic,
//!   never by which worker arrived first, so the serialized `.czb` stream
//!   is byte-identical for every thread count.
//! * **Allocation-free steady state** — every worker owns its scratch
//!   (batch buffer, block gather, encode scratch, shuffle buffer) and the
//!   wavelet transform uses a thread-local line pool; the per-block loop
//!   performs no heap allocation.
use super::format::{ChunkEntry, CoeffCodec, CzbFile, ShuffleMode, Stage1};
use super::stage1::{codec_for, Stage1Codec, Stage1Scratch};
use crate::cluster::{self, Execute, ScopedExec, SpanQueue};
use crate::codec::{shuffle, Codec};
use crate::core::block::{Block, BlockGrid};
use crate::core::{Field3, FieldStats};
use crate::wavelet::{self, WaveletKind};

/// Pluggable executor for the batched wavelet transform: native Rust or
/// the PJRT executable built from the Pallas kernel (`runtime::PjrtEngine`).
pub trait WaveletEngine: Sync {
    /// In-place forward transform of `n` contiguous bs³ blocks.
    fn forward_batch(&self, kind: WaveletKind, blocks: &mut [f32], bs: usize, levels: usize);
    /// In-place inverse transform of `n` contiguous bs³ blocks.
    fn inverse_batch(&self, kind: WaveletKind, blocks: &mut [f32], bs: usize, levels: usize);
    fn name(&self) -> &'static str;
}

/// Pure-Rust engine (default; also used for decompression).
pub struct NativeEngine;

impl WaveletEngine for NativeEngine {
    fn forward_batch(&self, kind: WaveletKind, blocks: &mut [f32], bs: usize, levels: usize) {
        wavelet::transform3d::forward_batch(kind, blocks, bs, levels);
    }
    fn inverse_batch(&self, kind: WaveletKind, blocks: &mut [f32], bs: usize, levels: usize) {
        wavelet::transform3d::inverse_batch(kind, blocks, bs, levels);
    }
    fn name(&self) -> &'static str {
        "native"
    }
}

/// Pipeline configuration (compile-time options in the paper; runtime here).
#[derive(Clone, Copy, Debug)]
pub struct PipelineConfig {
    pub bs: usize,
    pub stage1: Stage1,
    pub stage2: Codec,
    pub shuffle: ShuffleMode,
    /// Private per-thread buffer capacity before stage 2 runs (paper: 4 MB).
    /// Also the scheduling granularity: workers pull `chunk_bytes` worth
    /// of raw blocks per queue operation.
    pub chunk_bytes: usize,
    /// Blocks per engine batch (matches the PJRT executable's batch dim).
    pub batch: usize,
    pub nthreads: usize,
}

impl PipelineConfig {
    pub fn new(bs: usize, stage1: Stage1, stage2: Codec) -> Self {
        Self {
            bs,
            stage1,
            stage2,
            shuffle: ShuffleMode::None,
            chunk_bytes: 4 << 20,
            batch: 16,
            nthreads: 1,
        }
    }

    /// The paper's production scheme: W³ai + shuffle + ZLIB.
    pub fn paper_default(eps_rel: f32) -> Self {
        let mut c = Self::new(
            32,
            Stage1::Wavelet { kind: WaveletKind::Avg3, eps_rel, zbits: 0, coeff: CoeffCodec::None },
            Codec::ZlibDef,
        );
        c.shuffle = ShuffleMode::Byte4;
        c
    }

    pub fn with_shuffle(mut self, s: ShuffleMode) -> Self {
        self.shuffle = s;
        self
    }

    pub fn with_threads(mut self, n: usize) -> Self {
        self.nthreads = n.max(1);
        self
    }
}

/// Result of compressing one field.
#[derive(Clone, Debug)]
pub struct CompressStats {
    pub raw_bytes: usize,
    pub compressed_bytes: usize,
    pub nblocks: usize,
    pub nchunks: usize,
    pub stats: FieldStats,
    /// Wall-clock seconds spent in stage 1 (transform + encode), summed
    /// over threads.
    pub t_stage1: f64,
    /// Wall-clock seconds spent in stage 2 (shuffle + lossless codec).
    pub t_stage2: f64,
}

impl CompressStats {
    pub fn ratio(&self) -> f64 {
        self.raw_bytes as f64 / self.compressed_bytes as f64
    }
}

/// Encode one already-transformed (if wavelet) block into `out` with its
/// u32 size prefix. Scheme bytes come from the registered
/// [`Stage1Codec`]; only the prefix framing lives here.
fn encode_block_payload(
    codec: &dyn Stage1Codec,
    params: &Stage1,
    block: &[f32],
    bs: usize,
    eps_abs: f32,
    out: &mut Vec<u8>,
    scratch: &mut Stage1Scratch,
) {
    let start = out.len();
    out.extend_from_slice(&[0u8; 4]);
    codec.encode_block(params, block, bs, eps_abs, out, scratch);
    let size = (out.len() - start - 4) as u32;
    out[start..start + 4].copy_from_slice(&size.to_le_bytes());
}

/// Absolute stage-1 parameter from the relative one and the field range.
pub fn eps_abs_of(params: &Stage1, range: f32) -> f32 {
    let range = range.max(f32::MIN_POSITIVE);
    codec_for(params).eps_abs(params, range)
}

/// Raw blocks-per-span for the scheduler: ~`chunk_bytes` of raw field data
/// (block payload + u32 size prefix). Thread-count independent by design.
pub(crate) fn blocks_per_span(bs: usize, chunk_bytes: usize) -> usize {
    let block_raw = bs * bs * bs * 4 + 4;
    (chunk_bytes / block_raw).max(1)
}

struct ThreadChunk {
    first_block: u32,
    nblocks: u32,
    rawsize: u32,
    payload: Vec<u8>,
}

/// Seal a private buffer into a compressed chunk. `shuf` is the worker's
/// reusable shuffle buffer.
fn seal_chunk(
    raw: &mut Vec<u8>,
    first_block: u32,
    nblocks: u32,
    shuffle_mode: ShuffleMode,
    stage2: Codec,
    shuf: &mut Vec<u8>,
    chunks: &mut Vec<ThreadChunk>,
) {
    if nblocks == 0 {
        return;
    }
    let rawsize = raw.len() as u32;
    let to_compress: &[u8] = match shuffle_mode {
        ShuffleMode::None => raw,
        ShuffleMode::Byte4 => {
            shuffle::byte_shuffle_into(raw, 4, shuf);
            shuf
        }
        ShuffleMode::Bit4 => {
            shuffle::bit_shuffle_into(raw, 4, shuf);
            shuf
        }
    };
    let payload = stage2.compress_vec(to_compress);
    chunks.push(ThreadChunk { first_block, nblocks, rawsize, payload });
    raw.clear();
}

/// One compressed quantity before serialization: parsed header + chunk
/// payloads in block order. Frontends either concatenate it into a `Vec`
/// ([`compress_field`]) or stream it to an `io::Write`
/// (`Engine::compress`).
pub(crate) struct CompressedStream {
    pub(crate) czb: CzbFile,
    pub(crate) payloads: Vec<Vec<u8>>,
    pub(crate) stats: CompressStats,
}

/// Compress a whole field on the given executor. The resulting stream is
/// byte-identical for every `cfg.nthreads` and for every executor.
pub(crate) fn compress_field_core(
    exec: &dyn Execute,
    field: &Field3,
    name: &str,
    cfg: &PipelineConfig,
    engine: &dyn WaveletEngine,
) -> CompressedStream {
    let stats = FieldStats::compute(&field.data);
    let range = stats.range() as f32;
    let eps_abs = eps_abs_of(&cfg.stage1, range);
    let grid = BlockGrid::new(field, cfg.bs);
    let nblocks = grid.nblocks();

    // dynamic chunk-granular schedule over the shared atomic queue
    let queue = SpanQueue::new(nblocks, blocks_per_span(cfg.bs, cfg.chunk_bytes));
    let nthreads = cfg.nthreads.max(1).min(nblocks.max(1));
    let results =
        cluster::run_on(exec, nthreads, |_| worker(field, &grid, &queue, cfg, eps_abs, engine));

    // merge in block order and build the index
    let mut merged: Vec<ThreadChunk> = Vec::new();
    let (mut t1_total, mut t2_total) = (0.0f64, 0.0f64);
    for (chunks, t1, t2) in results {
        merged.extend(chunks);
        t1_total += t1;
        t2_total += t2;
    }
    merged.sort_by_key(|c| c.first_block);
    let mut chunks = Vec::with_capacity(merged.len());
    let header_size = CzbFile::header_size(name.len(), merged.len());
    let mut offset = header_size as u64;
    for c in &merged {
        chunks.push(ChunkEntry {
            offset,
            csize: c.payload.len() as u32,
            rawsize: c.rawsize,
            first_block: c.first_block,
            nblocks: c.nblocks,
        });
        offset += c.payload.len() as u64;
    }
    let czb = CzbFile {
        name: name.to_string(),
        nx: field.nx as u32,
        ny: field.ny as u32,
        nz: field.nz as u32,
        bs: cfg.bs as u32,
        stage1: cfg.stage1,
        stage2: cfg.stage2,
        shuffle: cfg.shuffle,
        global_min: stats.min as f32,
        global_max: stats.max as f32,
        nblocks: nblocks as u32,
        chunks,
    };
    let stats = CompressStats {
        raw_bytes: field.nbytes(),
        compressed_bytes: offset as usize,
        nblocks,
        nchunks: merged.len(),
        stats,
        t_stage1: t1_total,
        t_stage2: t2_total,
    };
    CompressedStream { czb, payloads: merged.into_iter().map(|c| c.payload).collect(), stats }
}

/// Compress a whole field. Returns the serialized `.czb` bytes + stats.
/// The output is byte-identical for every `cfg.nthreads`.
///
/// Deprecated entry point: one-shot convenience that spawns scoped
/// workers per call. Sessions that compress repeatedly (in-situ dumps,
/// method sweeps) should hold a [`super::Engine`], which drives the same
/// core over a persistent worker pool and produces identical bytes.
pub fn compress_field(
    field: &Field3,
    name: &str,
    cfg: &PipelineConfig,
    engine: &dyn WaveletEngine,
) -> (Vec<u8>, CompressStats) {
    let cs = compress_field_core(&ScopedExec, field, name, cfg, engine);
    let mut out = Vec::with_capacity(cs.stats.compressed_bytes);
    cs.czb.write_header(&mut out);
    for p in &cs.payloads {
        out.extend_from_slice(p);
    }
    debug_assert_eq!(out.len(), cs.stats.compressed_bytes);
    (out, cs.stats)
}

fn worker(
    field: &Field3,
    grid: &BlockGrid,
    queue: &SpanQueue,
    cfg: &PipelineConfig,
    eps_abs: f32,
    engine: &dyn WaveletEngine,
) -> (Vec<ThreadChunk>, f64, f64) {
    let bs = cfg.bs;
    let vol = bs * bs * bs;
    let levels = wavelet::max_levels(bs);
    let codec = codec_for(&cfg.stage1);
    let pre_transform = codec.pre_transform(&cfg.stage1);
    let batch = if pre_transform.is_some() { cfg.batch.max(1) } else { 1 };
    // worker-owned scratch, allocated once; the per-block loop below
    // performs no further heap allocation
    let mut batch_buf = vec![0f32; batch * vol];
    let mut raw: Vec<u8> = Vec::with_capacity(cfg.chunk_bytes + vol * 4 + 64);
    let mut shuf: Vec<u8> = Vec::new();
    let mut scratch = Stage1Scratch::default();
    let mut scratch_block = Block::zeros(bs);
    let mut chunks = Vec::new();
    let mut t1 = 0.0f64;
    let mut t2 = 0.0f64;
    while let Some(span) = queue.next_span() {
        let (lo, hi) = (span.start, span.end);
        let mut chunk_first = lo as u32;
        let mut chunk_count = 0u32;
        let mut id = lo;
        while id < hi {
            let n = batch.min(hi - id);
            let mut t = std::time::Instant::now();
            for j in 0..n {
                grid.extract(field, id + j, &mut scratch_block);
                batch_buf[j * vol..(j + 1) * vol].copy_from_slice(&scratch_block.data);
            }
            if let Some(kind) = pre_transform {
                engine.forward_batch(kind, &mut batch_buf[..n * vol], bs, levels);
            }
            for j in 0..n {
                encode_block_payload(
                    codec,
                    &cfg.stage1,
                    &batch_buf[j * vol..(j + 1) * vol],
                    bs,
                    eps_abs,
                    &mut raw,
                    &mut scratch,
                );
                chunk_count += 1;
                if raw.len() >= cfg.chunk_bytes {
                    t1 += t.elapsed().as_secs_f64();
                    let t2s = std::time::Instant::now();
                    seal_chunk(
                        &mut raw,
                        chunk_first,
                        chunk_count,
                        cfg.shuffle,
                        cfg.stage2,
                        &mut shuf,
                        &mut chunks,
                    );
                    t2 += t2s.elapsed().as_secs_f64();
                    chunk_first = (id + j + 1) as u32;
                    chunk_count = 0;
                    // restart the stage-1 clock: the seal already accounted
                    // for the elapsed stage-1 time (the seed double-counted
                    // it at batch end)
                    t = std::time::Instant::now();
                }
            }
            t1 += t.elapsed().as_secs_f64();
            id += n;
        }
        // chunk boundaries never cross spans: seal the remainder
        let t2s = std::time::Instant::now();
        seal_chunk(&mut raw, chunk_first, chunk_count, cfg.shuffle, cfg.stage2, &mut shuf, &mut chunks);
        t2 += t2s.elapsed().as_secs_f64();
    }
    (chunks, t1, t2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg32;

    fn smooth_field(n: usize, seed: u64) -> Field3 {
        let mut rng = Pcg32::new(seed);
        let data = crate::util::prop::gen_smooth_field(&mut rng, n);
        Field3::from_vec(n, n, n, data)
    }

    #[test]
    fn compress_produces_valid_header_and_ratio() {
        let f = smooth_field(64, 1);
        let cfg = PipelineConfig::paper_default(1e-3);
        let (bytes, st) = compress_field(&f, "p", &cfg, &NativeEngine);
        assert_eq!(st.raw_bytes, 64 * 64 * 64 * 4);
        assert!(st.ratio() > 3.0, "ratio {}", st.ratio());
        let (czb, _) = CzbFile::parse_header(&bytes).unwrap();
        assert_eq!(czb.nblocks, 8);
        assert_eq!(czb.name, "p");
        // chunk payload offsets must be consistent
        let total: u64 = czb.chunks.iter().map(|c| c.csize as u64).sum();
        assert_eq!(bytes.len() as u64, czb.chunks[0].offset + total);
    }

    #[test]
    fn multithreaded_matches_block_coverage() {
        let f = smooth_field(64, 2);
        for nthreads in [1, 2, 4, 7] {
            let cfg = PipelineConfig::paper_default(1e-3).with_threads(nthreads);
            let (bytes, _) = compress_field(&f, "p", &cfg, &NativeEngine);
            let (czb, _) = CzbFile::parse_header(&bytes).unwrap();
            let covered: u32 = czb.chunks.iter().map(|c| c.nblocks).sum();
            assert_eq!(covered, czb.nblocks, "nthreads {nthreads}");
            // chunks tile the block range without overlap
            let mut next = 0u32;
            for c in &czb.chunks {
                assert_eq!(c.first_block, next);
                next += c.nblocks;
            }
        }
    }

    #[test]
    fn output_is_byte_identical_across_thread_counts() {
        // the span queue fixes chunk boundaries by block-id arithmetic, so
        // scheduling must never leak into the stream
        let f = smooth_field(64, 21);
        let mut cfg = PipelineConfig::paper_default(1e-3);
        cfg.chunk_bytes = 32 << 10; // several spans, so pulls interleave
        let (base, st) = compress_field(&f, "p", &cfg.with_threads(1), &NativeEngine);
        assert!(st.nchunks > 1, "need a multi-chunk stream for this test");
        for nthreads in [2usize, 3, 8] {
            let (bytes, _) = compress_field(&f, "p", &cfg.with_threads(nthreads), &NativeEngine);
            assert_eq!(bytes, base, "nthreads {nthreads}");
        }
    }

    #[test]
    fn small_chunk_budget_makes_many_chunks() {
        let f = smooth_field(64, 3);
        let mut cfg = PipelineConfig::paper_default(1e-4);
        cfg.chunk_bytes = 16 << 10;
        let (bytes, st) = compress_field(&f, "p", &cfg, &NativeEngine);
        assert!(st.nchunks > 1, "nchunks {}", st.nchunks);
        let (czb, _) = CzbFile::parse_header(&bytes).unwrap();
        assert_eq!(czb.chunks.len(), st.nchunks);
    }

    #[test]
    fn all_stage1_schemes_produce_streams() {
        let f = smooth_field(32, 4);
        for stage1 in [
            Stage1::Copy,
            Stage1::Wavelet {
                kind: WaveletKind::Avg3,
                eps_rel: 1e-3,
                zbits: 0,
                coeff: CoeffCodec::None,
            },
            Stage1::Zfp { tol_rel: 1e-3 },
            Stage1::Sz { eb_rel: 1e-3 },
            Stage1::Fpzip { prec: 24 },
        ] {
            let cfg = PipelineConfig::new(32, stage1, Codec::ZlibDef);
            let (bytes, st) = compress_field(&f, "q", &cfg, &NativeEngine);
            assert!(bytes.len() > 32, "{stage1:?}");
            assert!(st.compressed_bytes == bytes.len());
        }
    }

    #[test]
    fn stage_timers_sum_sanely() {
        // regression for the stage-1 double-count: on a single thread the
        // per-stage times cannot exceed the end-to-end wall time
        let f = smooth_field(64, 5);
        let mut cfg = PipelineConfig::paper_default(1e-3);
        cfg.chunk_bytes = 16 << 10; // force mid-batch seals
        let t = std::time::Instant::now();
        let (_, st) = compress_field(&f, "p", &cfg, &NativeEngine);
        let wall = t.elapsed().as_secs_f64();
        assert!(
            st.t_stage1 + st.t_stage2 <= wall * 1.05 + 1e-3,
            "stage1 {} + stage2 {} vs wall {}",
            st.t_stage1,
            st.t_stage2,
            wall
        );
    }
}
