//! Substage-1 + substage-2 compression of a whole field (paper Fig. 1).
//!
//! Node-layer behaviour: worker threads pull contiguous spans of blocks
//! off a shared atomic work queue ([`crate::cluster::SpanQueue`]) —
//! dynamic chunk-granular scheduling instead of one static range per
//! thread, so a straggler can no longer serialize the tail of the field.
//! Each span holds ~`chunk_bytes` worth of raw blocks; the worker runs
//! stage 1 (transform + ε-encode) block by block into its private buffer
//! and stage 2 (shuffle + lossless codec) over each filled buffer,
//! emitting one chunk per span (plus deterministic mid-span seals if the
//! encoded stream outgrows the budget).
//!
//! Two invariants the scheduler maintains:
//! * **Determinism** — span boundaries are fixed by block-id arithmetic,
//!   never by which worker arrived first, so the serialized `.czb` stream
//!   is byte-identical for every thread count.
//! * **Allocation-free steady state** — every worker owns its scratch
//!   (batch buffer, block gather, encode scratch, shuffle buffer) and the
//!   wavelet transform uses a thread-local line pool; the per-block loop
//!   performs no heap allocation.
//!
//! Every run of this core is one *submission* on its executor: the
//! queue, scratch and abort state are all call-local, so any number of
//! threads may drive the same persistent pool concurrently (the
//! multi-generation [`crate::cluster::WorkerPool`]) without their
//! streams interacting — scheduling never leaks into the bytes.
//!
//! Stage 2 dispatches through the [`crate::codec::stage2`] registry and
//! seals every chunk as a *framed* container (fixed-arithmetic sub-frames,
//! `format.rs` v3). When the field yields fewer spans than workers — the
//! single-chunk / small-field regime where span parallelism starves — the
//! *wide path* ([`compress_wide`]) keeps the same byte-exact output while
//! fanning the inside of each span out across the pool: stage 1 encodes
//! block ranges in parallel, and each sealed chunk's sub-frames compress
//! in parallel.
use super::format::{ChunkEntry, CoeffCodec, CzbFile, ShuffleMode, Stage1, FORMAT_VERSION};
use super::quality::{block_quality, AchievedQuality, Bound, ChunkQuality};
use super::stage1::{codec_for, Stage1Codec, Stage1Scratch};
use crate::cluster::{self, Execute, ScopedExec, SpanQueue};
use crate::codec::stage2::{
    self, assemble_framed, compress_framed, frame_count, frame_span, Stage2Codec,
};
use crate::codec::{shuffle, Codec};
use crate::core::block::{Block, BlockGrid};
use crate::core::{Field3, FieldStats};
use crate::wavelet::{self, WaveletKind};

/// Pluggable executor for the batched wavelet transform: native Rust or
/// the PJRT executable built from the Pallas kernel (`runtime::PjrtEngine`).
/// `Send + Sync` so a `pipeline::Engine` session holding one stays
/// shareable across concurrently submitting threads.
pub trait WaveletEngine: Send + Sync {
    /// In-place forward transform of `n` contiguous bs³ blocks.
    fn forward_batch(&self, kind: WaveletKind, blocks: &mut [f32], bs: usize, levels: usize);
    /// In-place inverse transform of `n` contiguous bs³ blocks.
    fn inverse_batch(&self, kind: WaveletKind, blocks: &mut [f32], bs: usize, levels: usize);
    fn name(&self) -> &'static str;
}

/// Pure-Rust engine (default; also used for decompression).
pub struct NativeEngine;

impl WaveletEngine for NativeEngine {
    fn forward_batch(&self, kind: WaveletKind, blocks: &mut [f32], bs: usize, levels: usize) {
        wavelet::transform3d::forward_batch(kind, blocks, bs, levels);
    }
    fn inverse_batch(&self, kind: WaveletKind, blocks: &mut [f32], bs: usize, levels: usize) {
        wavelet::transform3d::inverse_batch(kind, blocks, bs, levels);
    }
    fn name(&self) -> &'static str {
        "native"
    }
}

/// Pipeline configuration (compile-time options in the paper; runtime here).
#[derive(Clone, Copy, Debug)]
pub struct PipelineConfig {
    pub bs: usize,
    pub stage1: Stage1,
    /// Error-bound contract. When not [`Bound::None`], the stage-1
    /// knob is resolved from it per field (via
    /// [`super::stage1::Stage1Codec::apply_bound`] against the field
    /// range) and the contract is recorded in the `.czb` v5 header.
    /// The configured codec must honor the bound's kind — callers
    /// validate the pairing before compressing.
    pub bound: Bound,
    pub stage2: Codec,
    pub shuffle: ShuffleMode,
    /// Private per-thread buffer capacity before stage 2 runs (paper: 4 MB).
    /// Also the scheduling granularity: workers pull `chunk_bytes` worth
    /// of raw blocks per queue operation.
    pub chunk_bytes: usize,
    /// Raw bytes per stage-2 sub-frame of a sealed chunk (`format.rs` v3
    /// framed container). Format-affecting: archives written with
    /// different frame budgets differ byte-wise. Smaller frames expose
    /// more intra-chunk parallelism at a slight ratio cost; `0` falls
    /// back to [`DEFAULT_FRAME_BYTES`] (a zero budget would degenerate
    /// to one frame per byte).
    pub frame_bytes: usize,
    /// Blocks per engine batch (matches the PJRT executable's batch dim).
    pub batch: usize,
    pub nthreads: usize,
}

/// Default raw bytes per stage-2 sub-frame: 16 frames per paper-default
/// 4 MiB chunk.
pub const DEFAULT_FRAME_BYTES: usize = 256 << 10;

impl PipelineConfig {
    pub fn new(bs: usize, stage1: Stage1, stage2: Codec) -> Self {
        Self {
            bs,
            stage1,
            bound: Bound::None,
            stage2,
            shuffle: ShuffleMode::None,
            chunk_bytes: 4 << 20,
            frame_bytes: DEFAULT_FRAME_BYTES,
            batch: 16,
            nthreads: 1,
        }
    }

    /// The paper's production scheme: W³ai + shuffle + ZLIB.
    pub fn paper_default(eps_rel: f32) -> Self {
        let mut c = Self::new(
            32,
            Stage1::Wavelet { kind: WaveletKind::Avg3, eps_rel, zbits: 0, coeff: CoeffCodec::None },
            Codec::ZlibDef,
        );
        c.shuffle = ShuffleMode::Byte4;
        c
    }

    pub fn with_shuffle(mut self, s: ShuffleMode) -> Self {
        self.shuffle = s;
        self
    }

    pub fn with_threads(mut self, n: usize) -> Self {
        self.nthreads = n.max(1);
        self
    }

    pub fn with_bound(mut self, b: Bound) -> Self {
        self.bound = b;
        self
    }
}

/// Result of compressing one field.
#[derive(Clone, Debug)]
pub struct CompressStats {
    pub raw_bytes: usize,
    pub compressed_bytes: usize,
    pub nblocks: usize,
    pub nchunks: usize,
    pub stats: FieldStats,
    /// Wall-clock seconds spent in stage 1 (transform + encode), summed
    /// over threads.
    pub t_stage1: f64,
    /// Wall-clock seconds spent in stage 2 (shuffle + lossless codec).
    pub t_stage2: f64,
    /// Quality the stream actually achieved, folded from the measured
    /// per-chunk column that the `.czb` v5 header records.
    pub quality: AchievedQuality,
}

impl CompressStats {
    pub fn ratio(&self) -> f64 {
        self.raw_bytes as f64 / self.compressed_bytes as f64
    }
}

/// Encode one already-transformed (if wavelet) block into `out` with its
/// u32 size prefix. Scheme bytes come from the registered
/// [`Stage1Codec`]; only the prefix framing lives here.
fn encode_block_payload(
    codec: &dyn Stage1Codec,
    params: &Stage1,
    block: &[f32],
    bs: usize,
    eps_abs: f32,
    out: &mut Vec<u8>,
    scratch: &mut Stage1Scratch,
) {
    let start = out.len();
    out.extend_from_slice(&[0u8; 4]);
    codec.encode_block(params, block, bs, eps_abs, out, scratch);
    let size = (out.len() - start - 4) as u32;
    out[start..start + 4].copy_from_slice(&size.to_le_bytes());
}

/// Absolute stage-1 parameter from the relative one and the field range.
pub fn eps_abs_of(params: &Stage1, range: f32) -> f32 {
    let range = range.max(f32::MIN_POSITIVE);
    codec_for(params).eps_abs(params, range)
}

/// Raw blocks-per-span for the scheduler: ~`chunk_bytes` of raw field data
/// (block payload + u32 size prefix). Thread-count independent by design.
pub(crate) fn blocks_per_span(bs: usize, chunk_bytes: usize) -> usize {
    let block_raw = bs * bs * bs * 4 + 4;
    (chunk_bytes / block_raw).max(1)
}

/// The frame granularity actually used for sealing AND recorded in the
/// header — `0` falls back to the default (never 1-byte frames), and the
/// value is clamped into the header field's u32 range so the recorded
/// number always agrees with the split the frames were cut at.
fn frame_raw_of(cfg: &PipelineConfig) -> usize {
    if cfg.frame_bytes == 0 {
        return DEFAULT_FRAME_BYTES;
    }
    cfg.frame_bytes.clamp(1, u32::MAX as usize)
}

struct ThreadChunk {
    first_block: u32,
    nblocks: u32,
    rawsize: u32,
    payload: Vec<u8>,
    /// Measured achieved error of this chunk's blocks (decode-after-
    /// encode), folded in block order.
    quality: ChunkQuality,
}

/// Apply the chunk preconditioner, returning the stage-2 input (either
/// `raw` untouched or the worker's reusable `shuf` buffer).
fn preconditioned<'a>(
    raw: &'a [u8],
    shuffle_mode: ShuffleMode,
    shuf: &'a mut Vec<u8>,
) -> &'a [u8] {
    match shuffle_mode {
        ShuffleMode::None => raw,
        ShuffleMode::Byte4 => {
            shuffle::byte_shuffle_into(raw, 4, shuf);
            shuf
        }
        ShuffleMode::Bit4 => {
            shuffle::bit_shuffle_into(raw, 4, shuf);
            shuf
        }
    }
}

/// Seal a private buffer into a compressed chunk: shuffle, then compress
/// as a framed container ([`compress_framed`]) through the registered
/// stage-2 codec. `shuf` is the worker's reusable shuffle buffer.
fn seal_chunk(
    raw: &mut Vec<u8>,
    first_block: u32,
    nblocks: u32,
    shuffle_mode: ShuffleMode,
    stage2: &dyn Stage2Codec,
    frame_raw: usize,
    shuf: &mut Vec<u8>,
    quality: ChunkQuality,
    chunks: &mut Vec<ThreadChunk>,
) {
    if nblocks == 0 {
        return;
    }
    let rawsize = raw.len() as u32;
    let to_compress = preconditioned(raw, shuffle_mode, shuf);
    let mut payload = Vec::new();
    compress_framed(stage2, to_compress, frame_raw, &mut payload);
    chunks.push(ThreadChunk { first_block, nblocks, rawsize, payload, quality });
    raw.clear();
}

/// One compressed quantity before serialization: parsed header + chunk
/// payloads in block order. Frontends either concatenate it into a `Vec`
/// ([`compress_field`]) or stream it to an `io::Write`
/// (`Engine::compress`).
pub(crate) struct CompressedStream {
    pub(crate) czb: CzbFile,
    pub(crate) payloads: Vec<Vec<u8>>,
    pub(crate) stats: CompressStats,
}

/// Compress a whole field on the given executor. The resulting stream is
/// byte-identical for every `cfg.nthreads` and for every executor: the
/// span-parallel and wide paths produce the same chunk boundaries, the
/// same frame boundaries, and therefore the same bytes.
pub(crate) fn compress_field_core(
    exec: &dyn Execute,
    field: &Field3,
    name: &str,
    cfg: &PipelineConfig,
    engine: &dyn WaveletEngine,
) -> CompressedStream {
    let stats = FieldStats::compute(&field.data);
    let range = stats.range() as f32;
    // resolve the contract onto the native knob now that the field
    // range is known; the resolved knob is what the header serializes.
    // honors() is validated where configs are built (CLI, engine,
    // service), so a failure here is a caller bug.
    let cfg = {
        let mut c = *cfg;
        if !matches!(c.bound, Bound::None) {
            c.stage1 = codec_for(&c.stage1)
                .apply_bound(&c.stage1, &c.bound, range)
                .expect("configured stage-1 codec honors the bound (validated at config time)");
        }
        c
    };
    let cfg = &cfg;
    let eps_abs = eps_abs_of(&cfg.stage1, range);
    let grid = BlockGrid::new(field, cfg.bs);
    let nblocks = grid.nblocks();
    let span = blocks_per_span(cfg.bs, cfg.chunk_bytes);
    let nspans = nblocks.div_ceil(span.max(1)).max(1);
    let nthreads = cfg.nthreads.max(1).min(nblocks.max(1));

    let (mut merged, t1_total, t2_total) = if nthreads > 1 && nspans < nthreads {
        // fewer spans than workers: span-granular scheduling would leave
        // most of the pool idle, so fan out *inside* each span instead
        compress_wide(exec, field, &grid, cfg, eps_abs, engine, nthreads)
    } else {
        // dynamic chunk-granular schedule over the shared atomic queue
        let queue = SpanQueue::new(nblocks, span);
        let results =
            cluster::run_on(exec, nthreads, |_| worker(field, &grid, &queue, cfg, eps_abs, engine));
        let mut merged: Vec<ThreadChunk> = Vec::new();
        let (mut t1_total, mut t2_total) = (0.0f64, 0.0f64);
        for (chunks, t1, t2) in results {
            merged.extend(chunks);
            t1_total += t1;
            t2_total += t2;
        }
        (merged, t1_total, t2_total)
    };
    merged.sort_by_key(|c| c.first_block);
    let mut chunks = Vec::with_capacity(merged.len());
    let header_size = CzbFile::header_size(name.len(), merged.len());
    let mut offset = header_size as u64;
    for c in &merged {
        chunks.push(ChunkEntry {
            offset,
            csize: c.payload.len() as u32,
            rawsize: c.rawsize,
            first_block: c.first_block,
            nblocks: c.nblocks,
        });
        offset += c.payload.len() as u64;
    }
    let chunk_quality: Vec<ChunkQuality> = merged.iter().map(|c| c.quality).collect();
    let czb = CzbFile {
        name: name.to_string(),
        nx: field.nx as u32,
        ny: field.ny as u32,
        nz: field.nz as u32,
        bs: cfg.bs as u32,
        stage1: cfg.stage1,
        stage2: cfg.stage2,
        shuffle: cfg.shuffle,
        version: FORMAT_VERSION,
        frame_raw: frame_raw_of(cfg) as u32,
        global_min: stats.min as f32,
        global_max: stats.max as f32,
        nblocks: nblocks as u32,
        chunks,
        chunk_crcs: merged.iter().map(|c| crate::util::crc32c::crc32c(&c.payload)).collect(),
        bound: cfg.bound,
        chunk_quality,
    };
    // fold the recorded column exactly the way a reader of this header
    // will, so `stats.quality` and `parse_header(..).achieved_quality()`
    // agree bit-for-bit
    let quality = czb.achieved_quality().expect("current writer version records quality");
    let stats = CompressStats {
        raw_bytes: field.nbytes(),
        compressed_bytes: offset as usize,
        nblocks,
        nchunks: merged.len(),
        stats,
        t_stage1: t1_total,
        t_stage2: t2_total,
        quality,
    };
    CompressedStream { czb, payloads: merged.into_iter().map(|c| c.payload).collect(), stats }
}

/// Compress a whole field. Returns the serialized `.czb` bytes + stats.
/// The output is byte-identical for every `cfg.nthreads`.
///
/// Deprecated entry point: one-shot convenience that spawns scoped
/// workers per call. Sessions that compress repeatedly (in-situ dumps,
/// method sweeps) should hold a [`super::Engine`], which drives the same
/// core over a persistent worker pool and produces identical bytes.
pub fn compress_field(
    field: &Field3,
    name: &str,
    cfg: &PipelineConfig,
    engine: &dyn WaveletEngine,
) -> (Vec<u8>, CompressStats) {
    let cs = compress_field_core(&ScopedExec, field, name, cfg, engine);
    let mut out = Vec::with_capacity(cs.stats.compressed_bytes);
    cs.czb.write_header(&mut out);
    for p in &cs.payloads {
        out.extend_from_slice(p);
    }
    debug_assert_eq!(out.len(), cs.stats.compressed_bytes);
    (out, cs.stats)
}

fn worker(
    field: &Field3,
    grid: &BlockGrid,
    queue: &SpanQueue,
    cfg: &PipelineConfig,
    eps_abs: f32,
    engine: &dyn WaveletEngine,
) -> (Vec<ThreadChunk>, f64, f64) {
    let bs = cfg.bs;
    let vol = bs * bs * bs;
    let levels = wavelet::max_levels(bs);
    let codec = codec_for(&cfg.stage1);
    let stage2 = stage2::by_id(cfg.stage2.id()).expect("stage-2 codec registered");
    let frame_raw = frame_raw_of(cfg);
    let pre_transform = codec.pre_transform(&cfg.stage1);
    let batch = if pre_transform.is_some() { cfg.batch.max(1) } else { 1 };
    // achieved-quality measurement: decode every encoded block back and
    // compare against the original samples. Copy is bit-exact, so its
    // column is zero without the decode.
    let measure = !matches!(cfg.stage1, Stage1::Copy);
    // worker-owned scratch, allocated once; the per-block loop below
    // performs no further heap allocation
    let mut batch_buf = vec![0f32; batch * vol];
    let mut orig_buf =
        if measure && pre_transform.is_some() { vec![0f32; batch * vol] } else { Vec::new() };
    let mut dec_buf = if measure { vec![0f32; vol] } else { Vec::new() };
    let mut raw: Vec<u8> = Vec::with_capacity(cfg.chunk_bytes + vol * 4 + 64);
    let mut shuf: Vec<u8> = Vec::new();
    let mut scratch = Stage1Scratch::default();
    let mut scratch_block = Block::zeros(bs);
    let mut chunks = Vec::new();
    let mut t1 = 0.0f64;
    let mut t2 = 0.0f64;
    while let Some(span) = queue.next_span() {
        let (lo, hi) = (span.start, span.end);
        let mut chunk_first = lo as u32;
        let mut chunk_count = 0u32;
        let mut chunk_q = ChunkQuality::ZERO;
        let mut id = lo;
        while id < hi {
            let n = batch.min(hi - id);
            let mut t = std::time::Instant::now();
            for j in 0..n {
                grid.extract(field, id + j, &mut scratch_block);
                batch_buf[j * vol..(j + 1) * vol].copy_from_slice(&scratch_block.data);
            }
            if let Some(kind) = pre_transform {
                // the forward transform overwrites the batch in place:
                // keep the original samples for the error measurement
                if measure {
                    orig_buf[..n * vol].copy_from_slice(&batch_buf[..n * vol]);
                }
                engine.forward_batch(kind, &mut batch_buf[..n * vol], bs, levels);
            }
            for j in 0..n {
                let pstart = raw.len();
                encode_block_payload(
                    codec,
                    &cfg.stage1,
                    &batch_buf[j * vol..(j + 1) * vol],
                    bs,
                    eps_abs,
                    &mut raw,
                    &mut scratch,
                );
                if measure {
                    codec
                        .decode_block(
                            &cfg.stage1,
                            &raw[pstart + 4..],
                            bs,
                            engine,
                            &mut scratch,
                            &mut dec_buf,
                        )
                        .expect("self-decode of a just-encoded block");
                    let orig = if pre_transform.is_some() {
                        &orig_buf[j * vol..(j + 1) * vol]
                    } else {
                        &batch_buf[j * vol..(j + 1) * vol]
                    };
                    chunk_q.merge(&block_quality(orig, &dec_buf));
                }
                chunk_count += 1;
                if raw.len() >= cfg.chunk_bytes {
                    t1 += t.elapsed().as_secs_f64();
                    let t2s = std::time::Instant::now();
                    seal_chunk(
                        &mut raw,
                        chunk_first,
                        chunk_count,
                        cfg.shuffle,
                        stage2,
                        frame_raw,
                        &mut shuf,
                        chunk_q,
                        &mut chunks,
                    );
                    t2 += t2s.elapsed().as_secs_f64();
                    chunk_first = (id + j + 1) as u32;
                    chunk_count = 0;
                    chunk_q = ChunkQuality::ZERO;
                    // restart the stage-1 clock: the seal already accounted
                    // for the elapsed stage-1 time (the seed double-counted
                    // it at batch end)
                    t = std::time::Instant::now();
                }
            }
            t1 += t.elapsed().as_secs_f64();
            id += n;
        }
        // chunk boundaries never cross spans: seal the remainder
        let t2s = std::time::Instant::now();
        seal_chunk(
            &mut raw,
            chunk_first,
            chunk_count,
            cfg.shuffle,
            stage2,
            frame_raw,
            &mut shuf,
            chunk_q,
            &mut chunks,
        );
        t2 += t2s.elapsed().as_secs_f64();
    }
    (chunks, t1, t2)
}

/// Intra-span parallel compression for the small-field regime
/// (`nspans < nthreads`): each span's blocks stage-1 encode in parallel
/// sub-ranges, the sealed chunks replicate the span worker's exact
/// boundary walk, and every chunk's sub-frames stage-2 compress in
/// parallel. Byte-identical to [`worker`] by construction — block
/// payloads, chunk boundaries, and frame boundaries are all fixed by
/// arithmetic, only the schedule differs.
fn compress_wide(
    exec: &dyn Execute,
    field: &Field3,
    grid: &BlockGrid,
    cfg: &PipelineConfig,
    eps_abs: f32,
    engine: &dyn WaveletEngine,
    nthreads: usize,
) -> (Vec<ThreadChunk>, f64, f64) {
    let bs = cfg.bs;
    let vol = bs * bs * bs;
    let levels = wavelet::max_levels(bs);
    let codec = codec_for(&cfg.stage1);
    let stage2 = stage2::by_id(cfg.stage2.id()).expect("stage-2 codec registered");
    let frame_raw = frame_raw_of(cfg);
    let pre_transform = codec.pre_transform(&cfg.stage1);
    let batch = if pre_transform.is_some() { cfg.batch.max(1) } else { 1 };
    let measure = !matches!(cfg.stage1, Stage1::Copy);
    let nblocks = grid.nblocks();
    let span = blocks_per_span(bs, cfg.chunk_bytes);
    let mut chunks: Vec<ThreadChunk> = Vec::new();
    let (mut t1, mut t2) = (0.0f64, 0.0f64);
    let mut shuf: Vec<u8> = Vec::new();
    let mut lo = 0usize;
    while lo < nblocks {
        let hi = (lo + span).min(nblocks);
        let t = std::time::Instant::now();
        // stage 1: encode the span's blocks in parallel sub-ranges; the
        // per-block bytes (and per-block quality records) are
        // position-independent, so merging the parts in block order
        // reproduces the serial stream exactly
        let queue = SpanQueue::new(hi - lo, batch);
        let m = nthreads.min(hi - lo).max(1);
        type WidePart = (usize, Vec<u8>, Vec<u32>, Vec<ChunkQuality>);
        let parts: Vec<Vec<WidePart>> = cluster::run_on(exec, m, |_| {
            let mut batch_buf = vec![0f32; batch * vol];
            let mut orig_buf = if measure && pre_transform.is_some() {
                vec![0f32; batch * vol]
            } else {
                Vec::new()
            };
            let mut dec_buf = if measure { vec![0f32; vol] } else { Vec::new() };
            let mut scratch = Stage1Scratch::default();
            let mut scratch_block = Block::zeros(bs);
            let mut mine = Vec::new();
            while let Some(sub) = queue.next_span() {
                let (slo, shi) = (lo + sub.start, lo + sub.end);
                let mut bytes = Vec::new();
                let mut sizes = Vec::with_capacity(shi - slo);
                let mut quals = Vec::with_capacity(if measure { shi - slo } else { 0 });
                let mut id = slo;
                while id < shi {
                    let n = batch.min(shi - id);
                    for j in 0..n {
                        grid.extract(field, id + j, &mut scratch_block);
                        batch_buf[j * vol..(j + 1) * vol].copy_from_slice(&scratch_block.data);
                    }
                    if let Some(kind) = pre_transform {
                        if measure {
                            orig_buf[..n * vol].copy_from_slice(&batch_buf[..n * vol]);
                        }
                        engine.forward_batch(kind, &mut batch_buf[..n * vol], bs, levels);
                    }
                    for j in 0..n {
                        let before = bytes.len();
                        encode_block_payload(
                            codec,
                            &cfg.stage1,
                            &batch_buf[j * vol..(j + 1) * vol],
                            bs,
                            eps_abs,
                            &mut bytes,
                            &mut scratch,
                        );
                        sizes.push((bytes.len() - before) as u32);
                        if measure {
                            codec
                                .decode_block(
                                    &cfg.stage1,
                                    &bytes[before + 4..],
                                    bs,
                                    engine,
                                    &mut scratch,
                                    &mut dec_buf,
                                )
                                .expect("self-decode of a just-encoded block");
                            let orig = if pre_transform.is_some() {
                                &orig_buf[j * vol..(j + 1) * vol]
                            } else {
                                &batch_buf[j * vol..(j + 1) * vol]
                            };
                            quals.push(block_quality(orig, &dec_buf));
                        }
                    }
                    id += n;
                }
                mine.push((slo, bytes, sizes, quals));
            }
            mine
        });
        let mut parts: Vec<WidePart> = parts.into_iter().flatten().collect();
        parts.sort_by_key(|p| p.0);
        let mut raw: Vec<u8> = Vec::new();
        let mut sizes: Vec<u32> = Vec::with_capacity(hi - lo);
        let mut quals: Vec<ChunkQuality> = Vec::new();
        for (_, bytes, s, q) in &parts {
            raw.extend_from_slice(bytes);
            sizes.extend_from_slice(s);
            quals.extend_from_slice(q);
        }
        t1 += t.elapsed().as_secs_f64();

        // seal walk: replicate the span worker's boundary rule exactly —
        // seal when the bytes since the last seal reach chunk_bytes,
        // folding the per-block quality records in the same block order
        let t2s = std::time::Instant::now();
        let mut chunk_first = lo;
        let mut chunk_count = 0u32;
        let mut chunk_q = ChunkQuality::ZERO;
        let mut start_byte = 0usize;
        let mut cum = 0usize;
        for (j, &sz) in sizes.iter().enumerate() {
            cum += sz as usize;
            chunk_count += 1;
            if measure {
                chunk_q.merge(&quals[j]);
            }
            if cum - start_byte >= cfg.chunk_bytes {
                seal_chunk_wide(
                    exec,
                    &raw[start_byte..cum],
                    chunk_first as u32,
                    chunk_count,
                    cfg.shuffle,
                    stage2,
                    frame_raw,
                    nthreads,
                    &mut shuf,
                    chunk_q,
                    &mut chunks,
                );
                start_byte = cum;
                chunk_first = lo + j + 1;
                chunk_count = 0;
                chunk_q = ChunkQuality::ZERO;
            }
        }
        seal_chunk_wide(
            exec,
            &raw[start_byte..cum],
            chunk_first as u32,
            chunk_count,
            cfg.shuffle,
            stage2,
            frame_raw,
            nthreads,
            &mut shuf,
            chunk_q,
            &mut chunks,
        );
        t2 += t2s.elapsed().as_secs_f64();
        lo = hi;
    }
    (chunks, t1, t2)
}

/// Seal one chunk with its sub-frames compressed in parallel on the
/// executor. Produces exactly [`seal_chunk`]'s bytes: the frame split is
/// the same arithmetic, only the frames' compression is concurrent.
fn seal_chunk_wide(
    exec: &dyn Execute,
    raw: &[u8],
    first_block: u32,
    nblocks: u32,
    shuffle_mode: ShuffleMode,
    stage2: &dyn Stage2Codec,
    frame_raw: usize,
    nthreads: usize,
    shuf: &mut Vec<u8>,
    quality: ChunkQuality,
    chunks: &mut Vec<ThreadChunk>,
) {
    if nblocks == 0 {
        return;
    }
    let rawsize = raw.len() as u32;
    let to_compress = preconditioned(raw, shuffle_mode, shuf);
    let n = frame_count(to_compress.len(), frame_raw);
    let mut payload = Vec::new();
    if n <= 1 || nthreads <= 1 {
        compress_framed(stage2, to_compress, frame_raw, &mut payload);
    } else {
        let queue = SpanQueue::new(n, 1);
        let parts: Vec<Vec<(usize, Vec<u8>)>> =
            cluster::run_on(exec, nthreads.min(n), |_| {
                let mut mine = Vec::new();
                while let Some(fr) = queue.next_span() {
                    for i in fr {
                        let span = frame_span(to_compress.len(), frame_raw, i);
                        let mut bytes = Vec::new();
                        stage2.compress_into(&to_compress[span], &mut bytes);
                        mine.push((i, bytes));
                    }
                }
                mine
            });
        let mut frames: Vec<(usize, Vec<u8>)> = parts.into_iter().flatten().collect();
        frames.sort_by_key(|f| f.0);
        debug_assert_eq!(frames.len(), n);
        let frames: Vec<Vec<u8>> = frames.into_iter().map(|(_, bytes)| bytes).collect();
        // same wire layout as the serial compress_framed path, via the
        // single shared container writer
        assemble_framed(&frames, &mut payload);
    }
    chunks.push(ThreadChunk { first_block, nblocks, rawsize, payload, quality });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg32;

    fn smooth_field(n: usize, seed: u64) -> Field3 {
        let mut rng = Pcg32::new(seed);
        let data = crate::util::prop::gen_smooth_field(&mut rng, n);
        Field3::from_vec(n, n, n, data)
    }

    #[test]
    fn compress_produces_valid_header_and_ratio() {
        let f = smooth_field(64, 1);
        let cfg = PipelineConfig::paper_default(1e-3);
        let (bytes, st) = compress_field(&f, "p", &cfg, &NativeEngine);
        assert_eq!(st.raw_bytes, 64 * 64 * 64 * 4);
        assert!(st.ratio() > 3.0, "ratio {}", st.ratio());
        let (czb, _) = CzbFile::parse_header(&bytes).unwrap();
        assert_eq!(czb.nblocks, 8);
        assert_eq!(czb.name, "p");
        // chunk payload offsets must be consistent
        let total: u64 = czb.chunks.iter().map(|c| c.csize as u64).sum();
        assert_eq!(bytes.len() as u64, czb.chunks[0].offset + total);
    }

    #[test]
    fn multithreaded_matches_block_coverage() {
        let f = smooth_field(64, 2);
        for nthreads in [1, 2, 4, 7] {
            let cfg = PipelineConfig::paper_default(1e-3).with_threads(nthreads);
            let (bytes, _) = compress_field(&f, "p", &cfg, &NativeEngine);
            let (czb, _) = CzbFile::parse_header(&bytes).unwrap();
            let covered: u32 = czb.chunks.iter().map(|c| c.nblocks).sum();
            assert_eq!(covered, czb.nblocks, "nthreads {nthreads}");
            // chunks tile the block range without overlap
            let mut next = 0u32;
            for c in &czb.chunks {
                assert_eq!(c.first_block, next);
                next += c.nblocks;
            }
        }
    }

    #[test]
    fn output_is_byte_identical_across_thread_counts() {
        // the span queue fixes chunk boundaries by block-id arithmetic, so
        // scheduling must never leak into the stream
        let f = smooth_field(64, 21);
        let mut cfg = PipelineConfig::paper_default(1e-3);
        cfg.chunk_bytes = 32 << 10; // several spans, so pulls interleave
        let (base, st) = compress_field(&f, "p", &cfg.with_threads(1), &NativeEngine);
        assert!(st.nchunks > 1, "need a multi-chunk stream for this test");
        for nthreads in [2usize, 3, 8] {
            let (bytes, _) = compress_field(&f, "p", &cfg.with_threads(nthreads), &NativeEngine);
            assert_eq!(bytes, base, "nthreads {nthreads}");
        }
    }

    #[test]
    fn small_chunk_budget_makes_many_chunks() {
        let f = smooth_field(64, 3);
        let mut cfg = PipelineConfig::paper_default(1e-4);
        cfg.chunk_bytes = 16 << 10;
        let (bytes, st) = compress_field(&f, "p", &cfg, &NativeEngine);
        assert!(st.nchunks > 1, "nchunks {}", st.nchunks);
        let (czb, _) = CzbFile::parse_header(&bytes).unwrap();
        assert_eq!(czb.chunks.len(), st.nchunks);
    }

    #[test]
    fn all_stage1_schemes_produce_streams() {
        let f = smooth_field(32, 4);
        for stage1 in [
            Stage1::Copy,
            Stage1::Wavelet {
                kind: WaveletKind::Avg3,
                eps_rel: 1e-3,
                zbits: 0,
                coeff: CoeffCodec::None,
            },
            Stage1::Zfp { tol_rel: 1e-3 },
            Stage1::Sz { eb_rel: 1e-3 },
            Stage1::Fpzip { prec: 24 },
        ] {
            let cfg = PipelineConfig::new(32, stage1, Codec::ZlibDef);
            let (bytes, st) = compress_field(&f, "q", &cfg, &NativeEngine);
            assert!(bytes.len() > 32, "{stage1:?}");
            assert!(st.compressed_bytes == bytes.len());
        }
    }

    #[test]
    fn wide_path_is_byte_identical_to_serial() {
        // nspans < nthreads routes through compress_wide: parallel
        // stage-1 block ranges + parallel sub-frame compression must
        // reproduce the serial worker's bytes exactly
        let f = smooth_field(64, 33);
        for (chunk_bytes, stage2) in
            [(4usize << 20, Codec::ZlibDef), (256 << 10, Codec::Lz4), (4 << 20, Codec::None)]
        {
            let mut cfg = PipelineConfig::paper_default(1e-3);
            cfg.chunk_bytes = chunk_bytes;
            cfg.stage2 = stage2;
            cfg.frame_bytes = 8 << 10; // many frames per chunk
            let (base, st) = compress_field(&f, "p", &cfg.with_threads(1), &NativeEngine);
            for nthreads in [2usize, 4, 8, 16] {
                let (bytes, stn) =
                    compress_field(&f, "p", &cfg.with_threads(nthreads), &NativeEngine);
                assert_eq!(bytes, base, "{stage2:?} chunk {chunk_bytes} t {nthreads}");
                assert_eq!(stn.nchunks, st.nchunks);
                assert_eq!(stn.compressed_bytes, st.compressed_bytes);
            }
        }
    }

    #[test]
    fn wide_path_replicates_mid_span_seals() {
        // incompressible data + tiny epsilon makes the encoded stream
        // outgrow the raw budget (wavelet adds a mask header), so a span
        // seals mid-walk; the wide path must reproduce those boundaries
        // bit-for-bit. bs=8: encoded noise block ~2120B vs 2052B raw, so
        // a 32-block span seals after 31 blocks.
        let n = 32usize;
        let mut rng = Pcg32::new(0x900D);
        let noise: Vec<f32> = (0..n * n * n).map(|_| rng.next_f32() * 2.0 - 1.0).collect();
        let f = Field3::from_vec(n, n, n, noise);
        let stage1 = Stage1::Wavelet {
            kind: WaveletKind::Avg3,
            eps_rel: 1e-7,
            zbits: 0,
            coeff: CoeffCodec::None,
        };
        let mut cfg = PipelineConfig::new(8, stage1, Codec::ZlibDef).with_shuffle(ShuffleMode::Byte4);
        cfg.chunk_bytes = 32 * (8 * 8 * 8 * 4 + 4); // exactly 32 raw blocks per span
        let (base, st) = compress_field(&f, "p", &cfg.with_threads(1), &NativeEngine);
        // 64 blocks -> 2 spans; mid-span seals make more chunks than spans
        assert!(st.nchunks > 2, "expected mid-span seals, got {} chunks", st.nchunks);
        for nthreads in [8usize, 16] {
            let (bytes, _) = compress_field(&f, "p", &cfg.with_threads(nthreads), &NativeEngine);
            assert_eq!(bytes, base, "nthreads {nthreads}");
        }
    }

    #[test]
    fn frame_budget_is_format_affecting_and_deterministic() {
        let f = smooth_field(64, 34);
        let mut cfg = PipelineConfig::paper_default(1e-3);
        cfg.frame_bytes = 32 << 10;
        let (a1, _) = compress_field(&f, "p", &cfg, &NativeEngine);
        let (a2, _) = compress_field(&f, "p", &cfg.with_threads(8), &NativeEngine);
        assert_eq!(a1, a2, "same frame budget must be thread-count independent");
        cfg.frame_bytes = 4 << 10;
        let (b, _) = compress_field(&f, "p", &cfg, &NativeEngine);
        assert_ne!(a1, b, "the frame budget is part of the format");
        let (file, _) = CzbFile::parse_header(&b).unwrap();
        assert_eq!(file.frame_raw, 4 << 10);
        // 0 means "default", never 1-byte frames
        cfg.frame_bytes = 0;
        let (z, _) = compress_field(&f, "p", &cfg, &NativeEngine);
        let (file, _) = CzbFile::parse_header(&z).unwrap();
        assert_eq!(file.frame_raw as usize, DEFAULT_FRAME_BYTES);
    }

    #[test]
    fn stage_timers_sum_sanely() {
        // regression for the stage-1 double-count: on a single thread the
        // per-stage times cannot exceed the end-to-end wall time
        let f = smooth_field(64, 5);
        let mut cfg = PipelineConfig::paper_default(1e-3);
        cfg.chunk_bytes = 16 << 10; // force mid-batch seals
        let t = std::time::Instant::now();
        let (_, st) = compress_field(&f, "p", &cfg, &NativeEngine);
        let wall = t.elapsed().as_secs_f64();
        assert!(
            st.t_stage1 + st.t_stage2 <= wall * 1.05 + 1e-3,
            "stage1 {} + stage2 {} vs wall {}",
            st.t_stage1,
            st.t_stage2,
            wall
        );
    }
}
