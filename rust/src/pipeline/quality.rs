//! Error-bound contracts: the cross-cutting quality layer.
//!
//! The stage-1 codecs each expose a *native* knob (`eps_rel`, `tol_rel`,
//! `eb_rel`, `prec`) whose meaning is codec-specific. This module turns
//! quality into a first-class [`Bound`] contract the user states once
//! (`--abs-err`/`--rel-err`/`--psnr`/`--lossless`) and every layer
//! threads through unchanged:
//!
//! * each [`super::stage1::Stage1Codec`] declares which [`BoundKind`]s it
//!   can honor and maps a bound to its native knob
//!   (`Stage1Codec::apply_bound`), keeping the existing knob fields as
//!   the wire encoding;
//! * compression *measures* the error it actually introduced — every
//!   encoded block is decoded back and compared against the original —
//!   and records one [`ChunkQuality`] per chunk in the `.czb` v5 header
//!   (plus the contract itself), in deterministic block order so v5
//!   streams stay byte-identical across thread counts and SIMD levels;
//! * readers fold the recorded column into an [`AchievedQuality`]
//!   (max abs/rel error, PSNR, compression ratio) and
//!   [`Bound::check`] compares it against the stored contract — what
//!   `czb verify --bounds` exits 3 on.
//!
//! The contract semantics are **pointwise and strict**: a codec may only
//! claim to honor a kind if its encoder guarantees the bound on every
//! sample (sz and zfp verify at encode time; copy and fpzip `prec=32`
//! are exact). The wavelet path's ε-threshold is *not* a pointwise bound
//! (level superposition can exceed it ~40-60x), so it honors only
//! [`Bound::None`].
//!
//! PSNR contracts reduce to relative ones: `rmse <= max_abs_err`, so a
//! pointwise bound of `range * 10^(-psnr/20)` guarantees
//! `20*log10(range/rmse) >= psnr`.

/// The kind of a [`Bound`], without its value — what codecs declare they
/// can honor and what travels in `czb codecs` listings.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BoundKind {
    /// No contract: the native knob is used as given.
    None,
    /// Bit-exact roundtrip.
    Lossless,
    /// Pointwise absolute error.
    Abs,
    /// Pointwise error relative to the global field range.
    Rel,
    /// Minimum peak signal-to-noise ratio in dB.
    Psnr,
}

impl BoundKind {
    pub const ALL: [BoundKind; 5] =
        [BoundKind::None, BoundKind::Lossless, BoundKind::Abs, BoundKind::Rel, BoundKind::Psnr];

    pub fn name(&self) -> &'static str {
        match self {
            BoundKind::None => "none",
            BoundKind::Lossless => "lossless",
            BoundKind::Abs => "abs-err",
            BoundKind::Rel => "rel-err",
            BoundKind::Psnr => "psnr",
        }
    }
}

/// An error-bound contract. `Abs`/`Rel`/`Psnr` values must be finite and
/// positive (enforced on every construction path: CLI flags, wire
/// decode, service frames).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Bound {
    /// No contract (the default; what every v≤4 archive reads as).
    None,
    /// Bit-exact roundtrip required.
    Lossless,
    /// Pointwise absolute error `<= value`.
    Abs(f64),
    /// Pointwise error relative to the global range `<= value`.
    Rel(f64),
    /// Achieved PSNR `>= value` dB.
    Psnr(f64),
}

/// Serialized size of a [`Bound`]: `u8` kind id + `f64` LE value.
pub const BOUND_WIRE_LEN: usize = 9;

impl Bound {
    pub fn kind(&self) -> BoundKind {
        match self {
            Bound::None => BoundKind::None,
            Bound::Lossless => BoundKind::Lossless,
            Bound::Abs(_) => BoundKind::Abs,
            Bound::Rel(_) => BoundKind::Rel,
            Bound::Psnr(_) => BoundKind::Psnr,
        }
    }

    /// Construct a valued bound, rejecting non-finite or non-positive
    /// tolerances — the single validation point all frontends share.
    pub fn new(kind: BoundKind, value: f64) -> Result<Self, String> {
        match kind {
            BoundKind::None => Ok(Bound::None),
            BoundKind::Lossless => Ok(Bound::Lossless),
            _ if !value.is_finite() || value <= 0.0 => {
                Err(format!("{} bound must be finite and > 0, got {value}", kind.name()))
            }
            BoundKind::Abs => Ok(Bound::Abs(value)),
            BoundKind::Rel => Ok(Bound::Rel(value)),
            BoundKind::Psnr => Ok(Bound::Psnr(value)),
        }
    }

    pub fn value(&self) -> f64 {
        match *self {
            Bound::Abs(v) | Bound::Rel(v) | Bound::Psnr(v) => v,
            Bound::None | Bound::Lossless => 0.0,
        }
    }

    /// Wire encoding: kind id byte + f64 LE value (0.0 for the valueless
    /// kinds).
    pub fn encode(&self) -> [u8; BOUND_WIRE_LEN] {
        let mut out = [0u8; BOUND_WIRE_LEN];
        out[0] = match self.kind() {
            BoundKind::None => 0,
            BoundKind::Lossless => 1,
            BoundKind::Abs => 2,
            BoundKind::Rel => 3,
            BoundKind::Psnr => 4,
        };
        out[1..9].copy_from_slice(&self.value().to_le_bytes());
        out
    }

    pub fn decode(b: &[u8; BOUND_WIRE_LEN]) -> Result<Self, String> {
        let value = f64::from_le_bytes(b[1..9].try_into().unwrap());
        let kind = match b[0] {
            0 => BoundKind::None,
            1 => BoundKind::Lossless,
            2 => BoundKind::Abs,
            3 => BoundKind::Rel,
            4 => BoundKind::Psnr,
            v => return Err(format!("bad bound kind id {v}")),
        };
        if matches!(kind, BoundKind::None | BoundKind::Lossless) && value != 0.0 {
            return Err(format!("{} bound carries a nonzero value", kind.name()));
        }
        Bound::new(kind, value)
    }

    /// Check a measured quality record against this contract.
    pub fn check(&self, q: &AchievedQuality) -> Result<(), String> {
        match *self {
            Bound::None => Ok(()),
            Bound::Lossless => {
                if q.max_abs_err == 0.0 {
                    Ok(())
                } else {
                    Err(format!("lossless contract violated: max abs err {:e}", q.max_abs_err))
                }
            }
            Bound::Abs(a) => {
                if q.max_abs_err <= a {
                    Ok(())
                } else {
                    Err(format!("abs-err contract {a:e} violated: achieved {:e}", q.max_abs_err))
                }
            }
            Bound::Rel(r) => {
                if q.max_rel_err <= r {
                    Ok(())
                } else {
                    Err(format!("rel-err contract {r:e} violated: achieved {:e}", q.max_rel_err))
                }
            }
            Bound::Psnr(p) => {
                if q.psnr_db >= p {
                    Ok(())
                } else {
                    Err(format!(
                        "psnr contract {p:.1} dB violated: achieved {:.1} dB",
                        q.psnr_db
                    ))
                }
            }
        }
    }

    /// Human rendering for CLI reports: "rel-err <= 1e-3", "psnr >= 60 dB".
    pub fn describe(&self) -> String {
        match *self {
            Bound::None => "none".into(),
            Bound::Lossless => "lossless".into(),
            Bound::Abs(a) => format!("abs-err <= {a:e}"),
            Bound::Rel(r) => format!("rel-err <= {r:e}"),
            Bound::Psnr(p) => format!("psnr >= {p} dB"),
        }
    }
}

/// Per-chunk achieved error, measured at compression time (decode every
/// encoded block, compare against the original samples) and serialized
/// in the `.czb` v5 header. Pure function of the chunk's blocks in block
/// order, so the column is identical across thread counts and SIMD
/// levels.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ChunkQuality {
    /// Largest pointwise `|orig - decoded|` in the chunk (`inf` if any
    /// sample decoded to a different NaN/∞ pattern).
    pub max_abs_err: f32,
    /// Sum over the chunk's samples of squared error, in f64 and block
    /// order (deterministic fold).
    pub sum_sq_err: f64,
}

/// Serialized size of one [`ChunkQuality`]: `f32` + `f64`, LE.
pub const CHUNK_QUALITY_WIRE_LEN: usize = 12;

impl ChunkQuality {
    pub const ZERO: ChunkQuality = ChunkQuality { max_abs_err: 0.0, sum_sq_err: 0.0 };

    pub fn encode(&self) -> [u8; CHUNK_QUALITY_WIRE_LEN] {
        let mut out = [0u8; CHUNK_QUALITY_WIRE_LEN];
        out[0..4].copy_from_slice(&self.max_abs_err.to_le_bytes());
        out[4..12].copy_from_slice(&self.sum_sq_err.to_le_bytes());
        out
    }

    pub fn decode(b: &[u8; CHUNK_QUALITY_WIRE_LEN]) -> Result<Self, String> {
        let max_abs_err = f32::from_le_bytes(b[0..4].try_into().unwrap());
        let sum_sq_err = f64::from_le_bytes(b[4..12].try_into().unwrap());
        if max_abs_err.is_nan() || max_abs_err < 0.0 {
            return Err(format!("bad chunk quality: max_abs_err {max_abs_err}"));
        }
        if sum_sq_err.is_nan() || sum_sq_err < 0.0 {
            return Err(format!("bad chunk quality: sum_sq_err {sum_sq_err}"));
        }
        Ok(Self { max_abs_err, sum_sq_err })
    }

    /// Fold another record in (block order on the caller).
    pub fn merge(&mut self, other: &ChunkQuality) {
        self.max_abs_err = self.max_abs_err.max(other.max_abs_err);
        self.sum_sq_err += other.sum_sq_err;
    }
}

/// Pointwise error of one decoded block against its original samples.
/// Bit-identical samples count as zero error (so NaN-preserving lossless
/// paths measure clean); a sample whose bits changed *to or from* a
/// non-finite value counts as infinite error.
pub fn block_quality(orig: &[f32], decoded: &[f32]) -> ChunkQuality {
    debug_assert_eq!(orig.len(), decoded.len());
    let mut q = ChunkQuality::ZERO;
    for (&a, &b) in orig.iter().zip(decoded) {
        if a.to_bits() == b.to_bits() {
            continue;
        }
        let d = (a - b).abs();
        if d.is_finite() {
            q.max_abs_err = q.max_abs_err.max(d);
            q.sum_sq_err += (d as f64) * (d as f64);
        } else {
            q.max_abs_err = f32::INFINITY;
            q.sum_sq_err = f64::INFINITY;
        }
    }
    q
}

/// The quality a stream actually achieved, folded from its recorded
/// per-chunk column. What `czb info` prints and [`Bound::check`] judges.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct AchievedQuality {
    /// Largest pointwise absolute error over every measured sample.
    pub max_abs_err: f64,
    /// `max_abs_err / range` (the global field range).
    pub max_rel_err: f64,
    /// `20*log10(range/rmse)` over the measured samples; `inf` when the
    /// roundtrip was exact.
    pub psnr_db: f64,
    /// Raw field bytes / compressed stream bytes.
    pub ratio: f64,
}

/// Serialized size of an [`AchievedQuality`]: four `f64`s, LE.
pub const ACHIEVED_WIRE_LEN: usize = 32;

impl AchievedQuality {
    /// Wire encoding for the `.czs` v3 per-quantity trailer metadata.
    pub fn encode(&self) -> [u8; ACHIEVED_WIRE_LEN] {
        let mut out = [0u8; ACHIEVED_WIRE_LEN];
        out[0..8].copy_from_slice(&self.max_abs_err.to_le_bytes());
        out[8..16].copy_from_slice(&self.max_rel_err.to_le_bytes());
        out[16..24].copy_from_slice(&self.psnr_db.to_le_bytes());
        out[24..32].copy_from_slice(&self.ratio.to_le_bytes());
        out
    }

    pub fn decode(b: &[u8; ACHIEVED_WIRE_LEN]) -> Result<Self, String> {
        let rd = |lo: usize| f64::from_le_bytes(b[lo..lo + 8].try_into().unwrap());
        let (max_abs_err, max_rel_err, psnr_db, ratio) = (rd(0), rd(8), rd(16), rd(24));
        // errors are non-negative by construction; PSNR may be any
        // non-NaN value including ±inf (exact roundtrips record +inf)
        if max_abs_err.is_nan() || max_abs_err < 0.0 || max_rel_err.is_nan() || max_rel_err < 0.0 {
            return Err(format!("bad achieved quality: errors {max_abs_err} / {max_rel_err}"));
        }
        if psnr_db.is_nan() {
            return Err("bad achieved quality: NaN psnr".into());
        }
        if !ratio.is_finite() || ratio < 0.0 {
            return Err(format!("bad achieved quality: ratio {ratio}"));
        }
        Ok(Self { max_abs_err, max_rel_err, psnr_db, ratio })
    }

    /// Fold a per-chunk column. `range` is the global field range,
    /// `nsamples` the number of samples the column measured (blocks ×
    /// bs³ — edge blocks are padded, and the padding is measured too).
    pub fn fold(
        chunks: &[ChunkQuality],
        range: f64,
        nsamples: u64,
        raw_bytes: u64,
        compressed_bytes: u64,
    ) -> Self {
        let mut total = ChunkQuality::ZERO;
        for c in chunks {
            total.merge(c);
        }
        let range = range.max(f64::MIN_POSITIVE);
        let max_abs_err = total.max_abs_err as f64;
        let psnr_db = if nsamples == 0 || total.sum_sq_err == 0.0 {
            f64::INFINITY
        } else {
            let rmse = (total.sum_sq_err / nsamples as f64).sqrt();
            20.0 * (range / rmse).log10()
        };
        AchievedQuality {
            max_abs_err,
            max_rel_err: max_abs_err / range,
            psnr_db,
            ratio: raw_bytes as f64 / (compressed_bytes.max(1)) as f64,
        }
    }
}

/// Shrink a mapped relative knob slightly below the contract so f32
/// knob arithmetic (`knob as f32 * range as f32`) can never round the
/// codec's threshold *above* the stated bound. The margin is far larger
/// than two f32 ulps and far smaller than any meaningful tolerance.
pub fn conservative_knob(rel: f64) -> f32 {
    (rel * (1.0 - 1e-5)) as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bound_wire_roundtrip_and_validation() {
        for b in [
            Bound::None,
            Bound::Lossless,
            Bound::Abs(1.5e-3),
            Bound::Rel(1e-4),
            Bound::Psnr(60.0),
        ] {
            let enc = b.encode();
            assert_eq!(Bound::decode(&enc).unwrap(), b);
        }
        // bad kind id
        let mut bad = Bound::None.encode();
        bad[0] = 9;
        assert!(Bound::decode(&bad).is_err());
        // non-finite / non-positive values
        for v in [f64::NAN, f64::INFINITY, -1.0, 0.0] {
            let mut b = Bound::Rel(1.0).encode();
            b[1..9].copy_from_slice(&v.to_le_bytes());
            assert!(Bound::decode(&b).is_err(), "rel {v} must be rejected");
            assert!(Bound::new(BoundKind::Abs, v).is_err());
            assert!(Bound::new(BoundKind::Psnr, v).is_err());
        }
        // valueless kinds must carry a zero value on the wire
        let mut b = Bound::Lossless.encode();
        b[1] = 1;
        assert!(Bound::decode(&b).is_err());
    }

    #[test]
    fn chunk_quality_wire_roundtrip_and_validation() {
        for q in [
            ChunkQuality::ZERO,
            ChunkQuality { max_abs_err: 1.25e-3, sum_sq_err: 4.5 },
            ChunkQuality { max_abs_err: f32::INFINITY, sum_sq_err: f64::INFINITY },
        ] {
            assert_eq!(ChunkQuality::decode(&q.encode()).unwrap(), q);
        }
        let mut bad = ChunkQuality::ZERO.encode();
        bad[0..4].copy_from_slice(&f32::NAN.to_le_bytes());
        assert!(ChunkQuality::decode(&bad).is_err());
        let mut bad = ChunkQuality::ZERO.encode();
        bad[0..4].copy_from_slice(&(-1.0f32).to_le_bytes());
        assert!(ChunkQuality::decode(&bad).is_err());
        let mut bad = ChunkQuality::ZERO.encode();
        bad[4..12].copy_from_slice(&(-4.0f64).to_le_bytes());
        assert!(ChunkQuality::decode(&bad).is_err());
    }

    #[test]
    fn block_quality_measures_pointwise_error() {
        let orig = [1.0f32, 2.0, -3.0, 0.5];
        let same = orig;
        assert_eq!(block_quality(&orig, &same), ChunkQuality::ZERO);
        let close = [1.25f32, 2.0, -3.5, 0.5];
        let q = block_quality(&orig, &close);
        assert_eq!(q.max_abs_err, 0.5);
        assert!((q.sum_sq_err - (0.0625 + 0.25)).abs() < 1e-12);
        // identical NaN bits are zero error; a NaN appearing is infinite
        let nan_in = [f32::NAN, 1.0];
        assert_eq!(block_quality(&nan_in, &nan_in), ChunkQuality::ZERO);
        let q = block_quality(&[1.0, 2.0], &[f32::NAN, 2.0]);
        assert_eq!(q.max_abs_err, f32::INFINITY);
    }

    #[test]
    fn achieved_quality_folds_and_checks() {
        let chunks = [
            ChunkQuality { max_abs_err: 1e-3, sum_sq_err: 1e-6 },
            ChunkQuality { max_abs_err: 2e-3, sum_sq_err: 3e-6 },
        ];
        let q = AchievedQuality::fold(&chunks, 2.0, 1000, 4000, 400);
        assert_eq!(q.max_abs_err, 2e-3_f32 as f64);
        assert!((q.max_rel_err - q.max_abs_err / 2.0).abs() < 1e-15);
        assert!((q.ratio - 10.0).abs() < 1e-12);
        let rmse = (4e-6f64 / 1000.0).sqrt();
        assert!((q.psnr_db - 20.0 * (2.0f64 / rmse).log10()).abs() < 1e-9);

        assert!(Bound::None.check(&q).is_ok());
        assert!(Bound::Abs(2e-3_f32 as f64).check(&q).is_ok());
        assert!(Bound::Abs(1e-3).check(&q).is_err());
        assert!(Bound::Rel(1.1e-3).check(&q).is_ok());
        assert!(Bound::Rel(0.9e-3).check(&q).is_err());
        assert!(Bound::Psnr(q.psnr_db - 1.0).check(&q).is_ok());
        assert!(Bound::Psnr(q.psnr_db + 1.0).check(&q).is_err());
        assert!(Bound::Lossless.check(&q).is_err());

        // exact roundtrip: infinite PSNR, lossless holds
        let q0 = AchievedQuality::fold(&[ChunkQuality::ZERO], 1.0, 10, 40, 40);
        assert_eq!(q0.psnr_db, f64::INFINITY);
        assert!(Bound::Lossless.check(&q0).is_ok());
        assert!(Bound::Psnr(200.0).check(&q0).is_ok());
    }

    #[test]
    fn achieved_quality_wire_roundtrip_and_validation() {
        for q in [
            AchievedQuality { max_abs_err: 0.0, max_rel_err: 0.0, psnr_db: f64::INFINITY, ratio: 4.0 },
            AchievedQuality { max_abs_err: 2e-3, max_rel_err: 1e-3, psnr_db: 61.5, ratio: 38.2 },
            AchievedQuality { max_abs_err: 5.0, max_rel_err: 2.5, psnr_db: -3.0, ratio: 1.0 },
        ] {
            assert_eq!(AchievedQuality::decode(&q.encode()).unwrap(), q);
        }
        let good = AchievedQuality { max_abs_err: 1.0, max_rel_err: 0.5, psnr_db: 6.0, ratio: 2.0 };
        for (lo, v) in [(0usize, -1.0f64), (0, f64::NAN), (8, -0.5), (16, f64::NAN), (24, f64::NAN), (24, f64::INFINITY)] {
            let mut b = good.encode();
            b[lo..lo + 8].copy_from_slice(&v.to_le_bytes());
            assert!(AchievedQuality::decode(&b).is_err(), "field at {lo} = {v} accepted");
        }
    }

    #[test]
    fn conservative_knob_stays_below_contract_after_f32_rounding() {
        for rel in [1e-1f64, 1e-3, 1e-6, 0.5] {
            for range in [1e-30f32, 1.0, 3.7e4, 1e30] {
                let knob = conservative_knob(rel);
                let eps_abs = knob * range;
                assert!(
                    (eps_abs as f64) <= rel * range as f64,
                    "rel {rel} range {range}: eps_abs {eps_abs} overshoots"
                );
            }
        }
    }
}
