//! Word-parallel 64x64 bit-matrix transpose.
//!
//! The zfp cell coder views a 4^3 block as 64 values x 64 bit planes;
//! encoding gathers one bit from every value per plane (64 dependent
//! shift/mask ops per plane in the naive form). Transposing the whole
//! 64x64 bit matrix first — six rounds of masked delta-swaps, the same
//! technique `codec::shuffle::transpose8` uses at byte width — makes
//! every plane a plain word read. The orientation is LSB-first:
//! `out[r]` bit `c` == `in[c]` bit `r`, exactly the plane layout
//! `fpc::zfp` encodes, and the transform is an involution (decode runs
//! the same function).

/// Transpose a 64x64 bit matrix in place (LSB-first orientation:
/// after the call, word `r` holds old bit `r` of every word, word
/// index == bit index).
pub fn transpose64(a: &mut [u64; 64]) {
    let mut j = 32usize;
    let mut m: u64 = 0x0000_0000_ffff_ffff;
    while j != 0 {
        let mut k = 0usize;
        while k < 64 {
            let t = ((a[k] >> j) ^ a[k + j]) & m;
            a[k] ^= t << j;
            a[k + j] ^= t;
            k = (k + j + 1) & !j;
        }
        j >>= 1;
        m ^= m << j;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg32;

    fn naive(a: &[u64; 64]) -> [u64; 64] {
        let mut out = [0u64; 64];
        for (r, o) in out.iter_mut().enumerate() {
            for c in 0..64 {
                *o |= ((a[c] >> r) & 1) << c;
            }
        }
        out
    }

    #[test]
    fn matches_naive_bit_gather() {
        let mut rng = Pcg32::new(0xb17);
        for _ in 0..200 {
            let mut a = [0u64; 64];
            for v in a.iter_mut() {
                *v = rng.next_u64();
            }
            let want = naive(&a);
            let mut got = a;
            transpose64(&mut got);
            assert_eq!(got, want);
        }
    }

    #[test]
    fn single_bit_orientation() {
        // a lone bit r in word c must land as bit c of word r
        for c in [0usize, 1, 7, 31, 32, 63] {
            for r in [0usize, 1, 8, 30, 33, 63] {
                let mut a = [0u64; 64];
                a[c] = 1u64 << r;
                transpose64(&mut a);
                for (w, &v) in a.iter().enumerate() {
                    let want = if w == r { 1u64 << c } else { 0 };
                    assert_eq!(v, want, "bit ({r},{c}) landed wrong");
                }
            }
        }
    }

    #[test]
    fn is_an_involution() {
        let mut rng = Pcg32::new(0x1e5);
        for _ in 0..50 {
            let mut a = [0u64; 64];
            for v in a.iter_mut() {
                *v = rng.next_u64();
            }
            let orig = a;
            transpose64(&mut a);
            transpose64(&mut a);
            assert_eq!(a, orig);
        }
    }
}
