//! Runtime-dispatched SIMD kernels for the stage-1 hot loops.
//!
//! # Dispatch model
//!
//! One process-wide dispatch level is resolved lazily on first use
//! ([`level`]) and cached in an atomic: the best the host supports
//! (`is_x86_feature_detected!("avx2")` on x86_64; NEON is baseline on
//! aarch64), clamped by the `CZB_SIMD` environment variable
//! (`auto|avx2|neon|scalar`). Requesting a level the host cannot run
//! falls back to scalar — it never faults ([`resolve`] is pure and
//! unit-tested for exactly this). Hot paths read the level once per
//! call (or per block batch) and branch to an arch-gated kernel; every
//! `#[target_feature]` kernel is only reachable through that check, so
//! the unsafe contract is "dispatch said the feature exists".
//!
//! The active level is observable: `czb info` prints a `host simd`
//! line, `czb serve` logs it at startup, and the metrics export
//! carries `czb_build_info{simd="..."}`.
//!
//! # Bit-exactness contract
//!
//! Vector kernels are required to be **bit-identical** to the scalar
//! kernels, which stay in the tree verbatim as the equivalence oracle
//! (and as the fallback). For the integer kernels (zfp lifting,
//! negabinary, shuffles, fpzip residuals) this is automatic: lane ops
//! wrap exactly like release-mode scalar ops. For the f32 wavelet
//! lifting it is inherited from the `wavelet::lift1d` contract: plain
//! IEEE-754 single ops in a fixed order, **no FMA** (`mul_add` would
//! change results and break parity with the Pallas kernel) and no
//! reassociation. The vector formulation therefore never vectorizes
//! *within* a line — it runs the same op sequence over `LANES`
//! independent lines at once (one line per lane), so each element sees
//! exactly the scalar op tree. `vaddps`/`vmulps` per lane are the same
//! IEEE operations as scalar `addss`/`mulss`, including NaN and
//! subnormal behavior, so equality holds for every input bit pattern
//! (the property tests throw random NaN/subnormal bits at it).
//!
//! # Adding a vector kernel
//!
//! 1. Keep (or factor out) the scalar loop — it is the oracle and the
//!    fallback, not dead code.
//! 2. Write the arch kernel in a `#[cfg(target_arch = ...)]` block,
//!    `#[target_feature(enable = "avx2")]` on x86_64, with a
//!    `# Safety` note tying it to the dispatch check. Prefer a
//!    lane-per-independent-item layout over intra-item shuffling when
//!    f32 order matters.
//! 3. Dispatch on a [`SimdLevel`] parameter threaded from the public
//!    entry point (taking `level()` there), so tests can force both
//!    paths without touching the process-wide state.
//! 4. Add a fuzzed equivalence test (random lengths for tails, random
//!    bit patterns for floats) comparing against the scalar oracle,
//!    plus — if it feeds an archive format — a cross-level
//!    byte-identity test on whole streams.
//!
//! Follow-ups tracked in ROADMAP.md: AVX-512 (wider bit-plane and
//! lift kernels), a portable `std::simd` backend once stable, and an
//! 8x8 in-register transpose to vectorize the contiguous x-pass too.

use std::sync::atomic::{AtomicU8, Ordering};

pub mod bitmat;
pub mod lanes;

/// The dispatch level for the process: which kernel family stage-1
/// hot loops run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdLevel {
    /// Portable scalar kernels (the equivalence oracle).
    Scalar,
    /// 256-bit AVX2 kernels (x86_64, runtime-detected).
    Avx2,
    /// 128-bit NEON kernels (aarch64 baseline).
    Neon,
}

impl SimdLevel {
    pub fn name(&self) -> &'static str {
        match self {
            SimdLevel::Scalar => "scalar",
            SimdLevel::Avx2 => "avx2",
            SimdLevel::Neon => "neon",
        }
    }

    fn to_u8(self) -> u8 {
        match self {
            SimdLevel::Scalar => 0,
            SimdLevel::Avx2 => 1,
            SimdLevel::Neon => 2,
        }
    }

    fn from_u8(v: u8) -> SimdLevel {
        match v {
            1 => SimdLevel::Avx2,
            2 => SimdLevel::Neon,
            _ => SimdLevel::Scalar,
        }
    }
}

/// What the host can actually run, ignoring any override.
pub fn detect() -> SimdLevel {
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") {
            return SimdLevel::Avx2;
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        return SimdLevel::Neon;
    }
    #[allow(unreachable_code)]
    SimdLevel::Scalar
}

/// Clamp a `CZB_SIMD` request against what the host supports. A level
/// the host cannot run degrades to scalar — never a fault; anything
/// unrecognized (including "auto") means "best available".
pub fn resolve(requested: &str, detected: SimdLevel) -> SimdLevel {
    match requested.trim().to_ascii_lowercase().as_str() {
        "scalar" | "off" | "none" => SimdLevel::Scalar,
        "avx2" => {
            if detected == SimdLevel::Avx2 {
                SimdLevel::Avx2
            } else {
                SimdLevel::Scalar
            }
        }
        "neon" => {
            if detected == SimdLevel::Neon {
                SimdLevel::Neon
            } else {
                SimdLevel::Scalar
            }
        }
        _ => detected,
    }
}

const LEVEL_UNSET: u8 = 0xff;
static LEVEL: AtomicU8 = AtomicU8::new(LEVEL_UNSET);

/// The process-wide dispatch level: `detect()` clamped by `CZB_SIMD`,
/// resolved once and cached.
pub fn level() -> SimdLevel {
    let v = LEVEL.load(Ordering::Relaxed);
    if v != LEVEL_UNSET {
        return SimdLevel::from_u8(v);
    }
    let l = match std::env::var("CZB_SIMD") {
        Ok(req) => resolve(&req, detect()),
        Err(_) => detect(),
    };
    LEVEL.store(l.to_u8(), Ordering::Relaxed);
    l
}

/// Force the process-wide level (benches and the whole-archive
/// identity tests; kernel-level tests should pass a level explicitly
/// instead). Returns the previous level so callers can restore it.
pub fn override_level(l: SimdLevel) -> SimdLevel {
    let prev = level();
    LEVEL.store(l.to_u8(), Ordering::Relaxed);
    prev
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resolve_clamps_unavailable_levels_to_scalar() {
        // the "CZB_SIMD=avx2 on a non-AVX2 host" contract: degrade, never fault
        assert_eq!(resolve("avx2", SimdLevel::Scalar), SimdLevel::Scalar);
        assert_eq!(resolve("neon", SimdLevel::Scalar), SimdLevel::Scalar);
        assert_eq!(resolve("avx2", SimdLevel::Neon), SimdLevel::Scalar);
        assert_eq!(resolve("neon", SimdLevel::Avx2), SimdLevel::Scalar);
    }

    #[test]
    fn resolve_honors_requests_the_host_supports() {
        assert_eq!(resolve("avx2", SimdLevel::Avx2), SimdLevel::Avx2);
        assert_eq!(resolve("neon", SimdLevel::Neon), SimdLevel::Neon);
        assert_eq!(resolve("scalar", SimdLevel::Avx2), SimdLevel::Scalar);
        assert_eq!(resolve(" SCALAR ", SimdLevel::Avx2), SimdLevel::Scalar);
        assert_eq!(resolve("off", SimdLevel::Neon), SimdLevel::Scalar);
    }

    #[test]
    fn resolve_treats_auto_and_garbage_as_best_available() {
        for req in ["auto", "", "bogus", "AVX512"] {
            assert_eq!(resolve(req, SimdLevel::Avx2), SimdLevel::Avx2);
            assert_eq!(resolve(req, SimdLevel::Scalar), SimdLevel::Scalar);
        }
    }

    #[test]
    fn level_roundtrips_through_u8() {
        for l in [SimdLevel::Scalar, SimdLevel::Avx2, SimdLevel::Neon] {
            assert_eq!(SimdLevel::from_u8(l.to_u8()), l);
        }
    }
}
