//! Lane-width abstraction for the f32 lifting kernels.
//!
//! `F32Lanes` models "one value per independent wavelet line": the
//! generic 1D lifting code in `wavelet::lift1d` is written once over
//! this trait and instantiated at `f32` (the scalar oracle — `LANES ==
//! 1`) and at the arch vector types. Only plain IEEE add/sub/mul are
//! exposed, so a kernel written against the trait *cannot* introduce
//! FMA or reassociation — the bit-exactness contract is enforced by
//! construction (see `crate::simd`).

use std::ops::{Add, Mul, Sub};

/// A pack of `LANES` f32 values supporting exactly the operations the
/// lifting schemes need: splat, unaligned load/store, `+`, `-`, `*`.
pub trait F32Lanes: Copy + Add<Output = Self> + Sub<Output = Self> + Mul<Output = Self> {
    const LANES: usize;

    fn splat(v: f32) -> Self;

    /// # Safety
    /// `p` must be valid for reads of `LANES` consecutive `f32`s.
    unsafe fn load(p: *const f32) -> Self;

    /// # Safety
    /// `p` must be valid for writes of `LANES` consecutive `f32`s.
    unsafe fn store(self, p: *mut f32);
}

impl F32Lanes for f32 {
    const LANES: usize = 1;

    #[inline(always)]
    fn splat(v: f32) -> Self {
        v
    }

    #[inline(always)]
    unsafe fn load(p: *const f32) -> Self {
        *p
    }

    #[inline(always)]
    unsafe fn store(self, p: *mut f32) {
        *p = self;
    }
}

#[cfg(target_arch = "x86_64")]
pub use x86::F32x8;

#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::F32Lanes;
    use core::arch::x86_64::*;
    use std::ops::{Add, Mul, Sub};

    /// Eight independent lines, one per AVX lane. The wrapped ops are
    /// `vaddps`/`vsubps`/`vmulps` — lanewise IEEE single ops, bitwise
    /// equal to their scalar counterparts for every input pattern.
    #[derive(Clone, Copy)]
    pub struct F32x8(pub(crate) __m256);

    impl Add for F32x8 {
        type Output = Self;
        #[inline(always)]
        fn add(self, rhs: Self) -> Self {
            // SAFETY: only constructed on the AVX2 dispatch path
            F32x8(unsafe { _mm256_add_ps(self.0, rhs.0) })
        }
    }

    impl Sub for F32x8 {
        type Output = Self;
        #[inline(always)]
        fn sub(self, rhs: Self) -> Self {
            // SAFETY: as for Add
            F32x8(unsafe { _mm256_sub_ps(self.0, rhs.0) })
        }
    }

    impl Mul for F32x8 {
        type Output = Self;
        #[inline(always)]
        fn mul(self, rhs: Self) -> Self {
            // SAFETY: as for Add
            F32x8(unsafe { _mm256_mul_ps(self.0, rhs.0) })
        }
    }

    impl F32Lanes for F32x8 {
        const LANES: usize = 8;

        #[inline(always)]
        fn splat(v: f32) -> Self {
            // SAFETY: as for Add
            F32x8(unsafe { _mm256_set1_ps(v) })
        }

        #[inline(always)]
        unsafe fn load(p: *const f32) -> Self {
            F32x8(_mm256_loadu_ps(p))
        }

        #[inline(always)]
        unsafe fn store(self, p: *mut f32) {
            _mm256_storeu_ps(p, self.0);
        }
    }
}

#[cfg(target_arch = "aarch64")]
pub use arm::F32x4;

#[cfg(target_arch = "aarch64")]
mod arm {
    use super::F32Lanes;
    use core::arch::aarch64::*;
    use std::ops::{Add, Mul, Sub};

    /// Four independent lines, one per NEON lane. NEON is baseline on
    /// aarch64, so no runtime detection guards construction.
    // newer toolchains make baseline-feature intrinsics safe, turning
    // these unsafe blocks redundant — keep them for older compilers
    #[allow(unused_unsafe)]
    #[derive(Clone, Copy)]
    pub struct F32x4(pub(crate) float32x4_t);

    #[allow(unused_unsafe)]
    impl Add for F32x4 {
        type Output = Self;
        #[inline(always)]
        fn add(self, rhs: Self) -> Self {
            // SAFETY: NEON is baseline on aarch64
            F32x4(unsafe { vaddq_f32(self.0, rhs.0) })
        }
    }

    #[allow(unused_unsafe)]
    impl Sub for F32x4 {
        type Output = Self;
        #[inline(always)]
        fn sub(self, rhs: Self) -> Self {
            // SAFETY: NEON is baseline on aarch64
            F32x4(unsafe { vsubq_f32(self.0, rhs.0) })
        }
    }

    #[allow(unused_unsafe)]
    impl Mul for F32x4 {
        type Output = Self;
        #[inline(always)]
        fn mul(self, rhs: Self) -> Self {
            // SAFETY: NEON is baseline on aarch64
            F32x4(unsafe { vmulq_f32(self.0, rhs.0) })
        }
    }

    #[allow(unused_unsafe)]
    impl F32Lanes for F32x4 {
        const LANES: usize = 4;

        #[inline(always)]
        fn splat(v: f32) -> Self {
            // SAFETY: NEON is baseline on aarch64
            F32x4(unsafe { vdupq_n_f32(v) })
        }

        #[inline(always)]
        unsafe fn load(p: *const f32) -> Self {
            F32x4(vld1q_f32(p))
        }

        #[inline(always)]
        unsafe fn store(self, p: *mut f32) {
            vst1q_f32(p, self.0);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::prop_cases;

    #[test]
    fn scalar_lanes_are_the_identity_wrapper() {
        let a = <f32 as F32Lanes>::splat(1.5);
        let b = <f32 as F32Lanes>::splat(-2.0);
        assert_eq!((a + b * a).to_bits(), (1.5f32 + (-2.0f32) * 1.5f32).to_bits());
    }

    #[cfg(target_arch = "x86_64")]
    #[test]
    fn avx_lanes_match_scalar_ops_bit_for_bit() {
        if crate::simd::detect() != crate::simd::SimdLevel::Avx2 {
            return; // nothing to check on this host
        }
        prop_cases(0x1a9e5, 50, |rng, _| {
            let mut a = [0f32; 8];
            let mut b = [0f32; 8];
            for i in 0..8 {
                // raw bit patterns: NaNs, infs, subnormals included
                a[i] = f32::from_bits(rng.next_u32());
                b[i] = f32::from_bits(rng.next_u32());
            }
            let mut add = [0f32; 8];
            let mut sub = [0f32; 8];
            let mut mul = [0f32; 8];
            // SAFETY: detect() confirmed AVX2 above
            unsafe {
                let va = F32x8::load(a.as_ptr());
                let vb = F32x8::load(b.as_ptr());
                (va + vb).store(add.as_mut_ptr());
                (va - vb).store(sub.as_mut_ptr());
                (va * vb).store(mul.as_mut_ptr());
            }
            for i in 0..8 {
                assert_eq!(add[i].to_bits(), (a[i] + b[i]).to_bits());
                assert_eq!(sub[i].to_bits(), (a[i] - b[i]).to_bits());
                assert_eq!(mul[i].to_bits(), (a[i] * b[i]).to_bits());
            }
        });
    }
}
