//! `czb` — the CubismZ-RS command-line tool: generate synthetic cavitation
//! datasets, compress/decompress/recompress quantities, inspect streams,
//! and measure PSNR. (The CLI is hand-rolled; the offline image has no
//! clap.)
use cubismz::anyhow;
use cubismz::codec::Codec;
use cubismz::util::error::Result;
use cubismz::coordinator;
use cubismz::core::FieldStats;
use cubismz::distrib;
use cubismz::io::h5lite;
use cubismz::pipeline::{
    AchievedQuality, Bound, BoundKind, CoeffCodec, CompressParams, CzbFile, DatasetOptions,
    Engine, NativeEngine, PipelineConfig, ShuffleMode, Stage1, WaveletEngine,
    DEFAULT_DATASET_CACHE_CHUNKS,
};
use cubismz::runtime::{default_artifacts_dir, PjrtEngine};
use cubismz::service;
use cubismz::sim::{step_to_time, CloudConfig, CloudSim, Qoi};
use cubismz::wavelet::WaveletKind;
use std::collections::HashMap;
use std::path::PathBuf;

struct Args {
    flags: HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Result<Self> {
        let mut flags = HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                let val = if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    i += 1;
                    argv[i].clone()
                } else {
                    "true".to_string()
                };
                flags.insert(name.to_string(), val);
            } else {
                return Err(anyhow!("unexpected argument {a}"));
            }
            i += 1;
        }
        Ok(Self { flags })
    }

    fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    fn req(&self, name: &str) -> Result<&str> {
        self.get(name).ok_or_else(|| anyhow!("missing --{name}"))
    }

    fn num<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("bad value for --{name}: {v}")),
        }
    }

    fn flag(&self, name: &str) -> bool {
        self.get(name).is_some()
    }

    /// Reject flags the command does not know (sorted so the error is
    /// deterministic). A typo like `--treads 8` must be a usage error,
    /// not a silently ignored no-op that runs single-threaded.
    fn check_known(&self, cmd: &str, allowed: &[&str]) -> Result<()> {
        let mut unknown: Vec<&str> = self
            .flags
            .keys()
            .map(|k| k.as_str())
            .filter(|k| !allowed.contains(k))
            .collect();
        unknown.sort_unstable();
        match unknown.first() {
            None => Ok(()),
            Some(k) => Err(anyhow!("unknown flag --{k} for `czb {cmd}`")),
        }
    }
}

/// The flags each subcommand accepts (`None` = unknown command).
/// `scheme` commands share the pipeline-parameter flags consumed by
/// [`config_of`]/[`session_of`].
fn allowed_flags(cmd: &str) -> Option<Vec<&'static str>> {
    const SCHEME: &[&str] = &[
        "scheme",
        "wavelet",
        "eps",
        "abs-err",
        "rel-err",
        "psnr",
        "lossless",
        "prec",
        "zbits",
        "coeff",
        "stage2",
        "shuffle",
        "bs",
        "chunk-bytes",
        "frame-bytes",
        "threads",
        "engine",
    ];
    let (base, scheme): (&[&str], bool) = match cmd {
        "gen" => (&["size", "step", "out", "bubbles", "production", "qoi"], false),
        "compress" => (&["in", "dataset", "out", "jobs"], true),
        "decompress" => (&["in", "out", "salvage", "jobs"], true),
        "recompress" => (&["in", "out"], true),
        "compress-dataset" => (&["in", "out", "qoi"], true),
        "decompress-dataset" => (&["in", "out", "cache-chunks"], true),
        "shard-compress" => (
            &[
                "in",
                "out",
                "qoi",
                "shards",
                "endpoints",
                "worker-threads",
                "bs",
                "eps",
                "shuffle",
                "abs-err",
                "rel-err",
                "psnr",
                "lossless",
            ],
            false,
        ),
        "shard-decompress" => (&["in", "out", "cache-chunks", "threads", "engine"], false),
        "shard-verify" => (&["in", "deep", "threads", "engine"], false),
        "verify" => (&["in", "deep", "bounds"], true),
        "tune" => (
            &[
                "size",
                "step",
                "qoi",
                "abs-err",
                "rel-err",
                "psnr",
                "lossless",
                "stage2",
                "shuffle",
                "bs",
                "chunk-bytes",
                "frame-bytes",
                "threads",
                "engine",
            ],
            false,
        ),
        "codecs" => (&[], false),
        "help" => (&[], false),
        "info" => (&["in", "cache-chunks"], false),
        "psnr" => (&["ref", "dataset", "in", "engine"], false),
        "serve" => (
            &[
                "addr",
                "threads",
                "admit",
                "admit-high",
                "retry-after-ms",
                "quota-capacity",
                "quota-rate",
                "max-body",
            ],
            false,
        ),
        "client" => (
            &[
                "addr", "op", "in", "out", "dataset", "eps", "abs-err", "rel-err", "psnr",
                "lossless", "bs", "shuffle", "tenant", "priority",
            ],
            false,
        ),
        _ => return None,
    };
    let mut v = base.to_vec();
    if scheme {
        v.extend_from_slice(SCHEME);
    }
    Some(v)
}

/// `--threads` flag with `default` when absent; 0 means all cores. Safe to
/// auto-thread: the compressed stream is thread-count independent.
fn threads_of(args: &Args, default: usize) -> Result<usize> {
    Ok(match args.num("threads", default)? {
        0 => std::thread::available_parallelism().map(|v| v.get()).unwrap_or(1),
        n => n,
    })
}

fn engine_of(args: &Args) -> Result<Box<dyn WaveletEngine>> {
    match args.get("engine").unwrap_or("native") {
        "native" => Ok(Box::new(NativeEngine)),
        "pjrt" => Ok(Box::new(PjrtEngine::new(default_artifacts_dir())?)),
        e => Err(anyhow!("unknown engine {e} (native|pjrt)")),
    }
}

/// `--shuffle` flag shared by `compress` and `client`: absent = none,
/// bare `--shuffle` keeps its historical meaning (byte shuffle), a
/// value names the mode.
fn shuffle_of(args: &Args) -> Result<ShuffleMode> {
    match args.get("shuffle") {
        None => Ok(ShuffleMode::None),
        Some("true") => Ok(ShuffleMode::Byte4),
        Some(name) => ShuffleMode::from_name(name)
            .ok_or_else(|| anyhow!("unknown shuffle mode {name} (none|byte4|bit4)")),
    }
}

/// The error-bound contract flags shared by the scheme commands and
/// `czb tune`: `--abs-err`/`--rel-err`/`--psnr` (valued, validated) and
/// `--lossless`, mutually exclusive. Absent = [`Bound::None`].
fn bound_of(args: &Args) -> Result<Bound> {
    let mut found: Vec<Bound> = Vec::new();
    if args.flag("lossless") {
        found.push(Bound::Lossless);
    }
    for (flag, kind) in
        [("abs-err", BoundKind::Abs), ("rel-err", BoundKind::Rel), ("psnr", BoundKind::Psnr)]
    {
        if let Some(v) = args.get(flag) {
            let value: f64 =
                v.parse().map_err(|_| anyhow!("bad value for --{flag}: {v}"))?;
            found.push(Bound::new(kind, value).map_err(|e| anyhow!("--{flag}: {e}"))?);
        }
    }
    match found.as_slice() {
        [] => Ok(Bound::None),
        [one] => Ok(*one),
        _ => Err(anyhow!("--abs-err, --rel-err, --psnr and --lossless are mutually exclusive")),
    }
}

fn config_of(args: &Args) -> Result<PipelineConfig> {
    let bs: usize = args.num("bs", 32)?;
    let eps: f32 = args.num("eps", 1e-3f32)?;
    if !eps.is_finite() || eps < 0.0 {
        return Err(anyhow!("--eps must be finite and >= 0, got {eps}"));
    }
    let bound = bound_of(args)?;
    if bound != Bound::None && args.get("eps").is_some() {
        return Err(anyhow!(
            "--eps (raw codec knob) conflicts with an error-bound flag; \
             state the contract alone and the knob is derived from it"
        ));
    }
    if args.get("eps").is_some() {
        eprintln!(
            "note: --eps sets the raw per-codec knob; prefer --abs-err/--rel-err/--psnr \
             for a recorded, verifiable contract (see docs/QUALITY.md)"
        );
    }
    let wavelet = match args.get("wavelet").unwrap_or("w3a") {
        "w4" => WaveletKind::Interp4,
        "w4l" => WaveletKind::Lift4,
        "w3a" => WaveletKind::Avg3,
        w => return Err(anyhow!("unknown wavelet {w} (w4|w4l|w3a)")),
    };
    let coeff = match args.get("coeff").unwrap_or("none") {
        "none" => CoeffCodec::None,
        "fpzip" => CoeffCodec::Fpzip,
        "sz" => CoeffCodec::Sz,
        "spdp" => CoeffCodec::Spdp,
        c => return Err(anyhow!("unknown coeff codec {c}")),
    };
    let stage1 = match args.get("scheme").unwrap_or("wavelet") {
        "wavelet" => Stage1::Wavelet {
            kind: wavelet,
            eps_rel: eps,
            zbits: args.num("zbits", 0u8)?,
            coeff,
        },
        "zfp" => Stage1::Zfp { tol_rel: eps },
        "sz" => Stage1::Sz { eb_rel: eps },
        "fpzip" => Stage1::Fpzip { prec: args.num("prec", 24u8)? },
        "fpzip-lossless" => Stage1::Fpzip { prec: 32 },
        "copy" => Stage1::Copy,
        s => return Err(anyhow!("unknown scheme {s}")),
    };
    // contract → scheme resolution: an explicit --scheme must honor the
    // stated bound kind (hard error otherwise); a defaulted scheme is
    // auto-selected for the contract. The codec maps the bound onto its
    // native knob against the field range at compression time.
    let stage1 = if bound == Bound::None {
        stage1
    } else if args.get("scheme").is_some() {
        let codec = cubismz::pipeline::stage1::codec_for(&stage1);
        if !codec.honors(bound.kind()) {
            return Err(anyhow!(
                "stage-1 codec '{}' cannot honor a {} bound (see `czb codecs` for what each \
                 codec guarantees)",
                codec.name(),
                bound.kind().name()
            ));
        }
        stage1
    } else {
        cubismz::pipeline::stage1::default_scheme_for(&bound)
            .expect("every non-None bound kind has a default scheme")
    };
    let stage2_name = args.get("stage2").unwrap_or("zlib");
    // alias-aware, case-insensitive lookup through the stage-2 registry:
    // every name `czb info` or `czb codecs` prints parses back here
    let stage2 =
        Codec::from_name(stage2_name).ok_or_else(|| anyhow!("unknown stage2 codec {stage2_name}"))?;
    let mut cfg = PipelineConfig::new(bs, stage1, stage2);
    cfg.bound = bound;
    cfg.shuffle = shuffle_of(args)?;
    cfg.nthreads = threads_of(args, 1)?;
    cfg.chunk_bytes = args.num("chunk-bytes", 4usize << 20)?;
    // one policy everywhere (CLI, EngineBuilder, PipelineConfig): 0 means
    // "use the default frame budget", never 1-byte frames
    cfg.frame_bytes = args.num("frame-bytes", cubismz::pipeline::DEFAULT_FRAME_BYTES)?;
    if cfg.frame_bytes == 0 {
        cfg.frame_bytes = cubismz::pipeline::DEFAULT_FRAME_BYTES;
    }
    Ok(cfg)
}

/// Build an [`Engine`] session from the shared CLI flags.
fn session_of(args: &Args, cfg: &PipelineConfig) -> Result<Engine> {
    Ok(Engine::builder()
        .threads(cfg.nthreads)
        .chunk_bytes(cfg.chunk_bytes)
        .frame_bytes(cfg.frame_bytes)
        .wavelet_engine(engine_of(args)?)
        .build())
}

fn cmd_gen(args: &Args) -> Result<()> {
    let n: usize = args.num("size", 128)?;
    let step: usize = args.num("step", 5000)?;
    let out = PathBuf::from(args.req("out")?);
    let cfg = if args.flag("production") {
        CloudConfig::production(n, args.num("bubbles", 600usize)?)
    } else {
        let mut c = CloudConfig::paper(n);
        c.n_bubbles = args.num("bubbles", 70usize)?;
        c
    };
    let sim = CloudSim::new(cfg);
    let t = step_to_time(step);
    let mut datasets = Vec::new();
    let only: Option<String> = args.get("qoi").map(|s| s.to_string());
    for qoi in Qoi::ALL {
        if let Some(o) = &only {
            if o != qoi.name() {
                continue;
            }
        }
        let f = sim.field(qoi, t);
        let st = FieldStats::compute(&f.data);
        println!("{:>4}  {}", qoi.name(), st.row());
        datasets.push(h5lite::Dataset::from_field(qoi.name(), &f));
    }
    h5lite::write(&out, &datasets)?;
    println!("wrote {} ({} datasets, step {step})", out.display(), datasets.len());
    Ok(())
}

/// `--jobs` flag: concurrent submitter threads of a multi-stream flow
/// (0 or absent = one submitter per stream).
fn jobs_of(args: &Args, nstreams: usize) -> Result<usize> {
    Ok(match args.num("jobs", 0usize)? {
        0 => nstreams.max(1),
        n => n,
    })
}

fn cmd_compress(args: &Args) -> Result<()> {
    let input = PathBuf::from(args.req("in")?);
    let dataset = args.req("dataset")?;
    let out = PathBuf::from(args.req("out")?);
    let cfg = config_of(args)?;
    let datasets: Vec<&str> =
        dataset.split(',').map(str::trim).filter(|s| !s.is_empty()).collect();
    if datasets.len() > 1 {
        // multi-stream flow: every dataset is one concurrent submission
        // on a single Engine session; --out names a directory
        let engine = session_of(args, &cfg)?;
        let params = CompressParams::from_config(&cfg);
        let jobs = jobs_of(args, datasets.len())?;
        // outputs are named by dataset: a repeated name would race-write
        // one .czb — refuse instead of silently clobbering
        for (i, name) in datasets.iter().enumerate() {
            if datasets[..i].contains(name) {
                return Err(anyhow!("duplicate dataset {name} in --dataset list"));
            }
        }
        std::fs::create_dir_all(&out)?;
        let batch: Vec<coordinator::CompressJob> = datasets
            .iter()
            .map(|name| coordinator::CompressJob {
                input: input.clone(),
                dataset: name.to_string(),
                output: out.join(format!("{name}.czb")),
            })
            .collect();
        let t = std::time::Instant::now();
        let stats = coordinator::compress_files(&batch, &params, &engine, jobs)?;
        let (mut raw, mut comp) = (0usize, 0usize);
        for ((name, st), job) in stats.iter().zip(&batch) {
            println!(
                "  {:>8} -> {}: {} -> {} bytes  CR {:.2}",
                name,
                job.output.display(),
                st.raw_bytes,
                st.compressed_bytes,
                st.ratio()
            );
            raw += st.raw_bytes;
            comp += st.compressed_bytes;
        }
        println!(
            "{} streams -> {}  CR {:.2}  ({:.3}s, {jobs} jobs x {} threads)",
            stats.len(),
            out.display(),
            raw as f64 / comp.max(1) as f64,
            t.elapsed().as_secs_f64(),
            engine.threads(),
        );
        return Ok(());
    }
    // single stream: use the cleaned element so a stray trailing comma
    // ("--dataset p,") does not leak into the lookup
    let dataset = *datasets.first().ok_or_else(|| anyhow!("empty --dataset"))?;
    let engine = engine_of(args)?;
    let t = std::time::Instant::now();
    let st = coordinator::compress_file(&input, dataset, &out, &cfg, engine.as_ref())?;
    println!(
        "{} -> {}: {} -> {} bytes  CR {:.2}  ({:.3}s, stage1 {:.3}s, stage2 {:.3}s, engine {})",
        dataset,
        out.display(),
        st.raw_bytes,
        st.compressed_bytes,
        st.ratio(),
        t.elapsed().as_secs_f64(),
        st.t_stage1,
        st.t_stage2,
        engine.name(),
    );
    Ok(())
}

/// `czb decompress --salvage`: decode every intact chunk of a damaged
/// file, zero-fill the corrupt ones, and enumerate what was lost. Exits
/// 3 when anything was lost so scripts can tell a lossy recovery from a
/// clean decode.
fn cmd_decompress_salvage(args: &Args) -> Result<()> {
    let input = PathBuf::from(args.req("in")?);
    let out = PathBuf::from(args.req("out")?);
    let mut cfg = config_of(args)?;
    cfg.nthreads = threads_of(args, 0)?;
    let engine = session_of(args, &cfg)?;
    let t = std::time::Instant::now();
    let reports = coordinator::salvage_file(&input, &out, &engine)?;
    let mut damaged = false;
    for (name, r) in &reports {
        match r {
            Ok(rep) if rep.is_clean() => {
                println!("  {:>8}: clean ({} chunks)", name, rep.total_chunks);
            }
            Ok(rep) => {
                damaged = true;
                println!(
                    "  {:>8}: salvaged {}/{} chunks ({} blocks zero-filled)",
                    name,
                    rep.salvaged_chunks(),
                    rep.total_chunks,
                    rep.lost_blocks
                );
                for (idx, why) in &rep.corrupt_chunks {
                    println!("           chunk {idx}: {why}");
                }
            }
            Err(e) => {
                damaged = true;
                println!("  {name:>8}: unreadable, skipped: {e}");
            }
        }
    }
    println!(
        "{} -> {} ({:.3}s, {} threads)",
        input.display(),
        out.display(),
        t.elapsed().as_secs_f64(),
        engine.threads(),
    );
    if damaged {
        std::process::exit(3);
    }
    Ok(())
}

fn cmd_decompress(args: &Args) -> Result<()> {
    if args.flag("salvage") {
        return cmd_decompress_salvage(args);
    }
    let input = args.req("in")?;
    let out = PathBuf::from(args.req("out")?);
    let inputs: Vec<&str> = input.split(',').map(str::trim).filter(|s| !s.is_empty()).collect();
    // a comma inside a real filename must not engage the multi-stream
    // flow: if the raw value names an existing file, it is one input
    if inputs.len() > 1 && !std::path::Path::new(input).is_file() {
        // multi-stream flow: every .czb is one concurrent submission on
        // a single Engine session; --out names a directory
        let mut cfg = config_of(args)?;
        // decompression historically defaults --threads to all cores
        cfg.nthreads = threads_of(args, 0)?;
        let engine = session_of(args, &cfg)?;
        let jobs = jobs_of(args, inputs.len())?;
        std::fs::create_dir_all(&out)?;
        let pairs: Vec<(PathBuf, PathBuf)> = inputs
            .iter()
            .map(|p| {
                let p = PathBuf::from(p);
                let stem = p
                    .file_stem()
                    .map(|s| s.to_string_lossy().into_owned())
                    .unwrap_or_else(|| "stream".to_string());
                let o = out.join(format!("{stem}.h5l"));
                (p, o)
            })
            .collect();
        // outputs are named by file stem: two inputs sharing a stem would
        // race-write one .h5l — refuse instead of silently clobbering
        for (i, (_, o)) in pairs.iter().enumerate() {
            if pairs[..i].iter().any(|(_, prev)| prev == o) {
                return Err(anyhow!(
                    "output collision: two inputs map to {} (same file stem); \
                     rename one or decompress it separately",
                    o.display()
                ));
            }
        }
        let t = std::time::Instant::now();
        let names = coordinator::decompress_files(&pairs, &engine, jobs)?;
        for (name, (i, o)) in names.iter().zip(&pairs) {
            println!("  {name}: {} -> {}", i.display(), o.display());
        }
        println!(
            "{} streams -> {} ({:.3}s, {jobs} jobs x {} threads)",
            names.len(),
            out.display(),
            t.elapsed().as_secs_f64(),
            engine.threads(),
        );
        return Ok(());
    }
    // single stream: a raw comma-bearing filename wins when it exists;
    // otherwise use the cleaned element so a stray trailing comma
    // ("--in a.czb,") does not leak into the path
    let input = if std::path::Path::new(input).is_file() {
        PathBuf::from(input)
    } else {
        PathBuf::from(*inputs.first().ok_or_else(|| anyhow!("empty --in"))?)
    };
    let engine = engine_of(args)?;
    let nthreads = threads_of(args, 0)?;
    let t = std::time::Instant::now();
    let (name, field) = coordinator::decompress_file(&input, &out, engine.as_ref(), nthreads)?;
    println!(
        "{} ({}x{}x{}) -> {} ({:.3}s, {nthreads} threads)",
        name,
        field.nx,
        field.ny,
        field.nz,
        out.display(),
        t.elapsed().as_secs_f64()
    );
    Ok(())
}

fn cmd_compress_dataset(args: &Args) -> Result<()> {
    let input = PathBuf::from(args.req("in")?);
    let out = PathBuf::from(args.req("out")?);
    let cfg = config_of(args)?;
    let engine = session_of(args, &cfg)?;
    let params = CompressParams::from_config(&cfg);
    let t = std::time::Instant::now();
    let per_q = coordinator::compress_dataset_file(&input, args.get("qoi"), &out, &params, &engine)?;
    let (mut raw, mut comp) = (0usize, 0usize);
    for (name, st) in &per_q {
        println!(
            "  {:>8}: {} -> {} bytes  CR {:.2}  ({} chunks)",
            name, st.raw_bytes, st.compressed_bytes, st.ratio(), st.nchunks
        );
        raw += st.raw_bytes;
        comp += st.compressed_bytes;
    }
    println!(
        "{} quantities -> {}  CR {:.2}  ({:.3}s, {} threads)",
        per_q.len(),
        out.display(),
        raw as f64 / comp.max(1) as f64,
        t.elapsed().as_secs_f64(),
        engine.threads(),
    );
    Ok(())
}

/// `--cache-chunks` flag: decoded chunks the archive-wide shared cache
/// holds (the `DATASET_CACHE_CHUNKS` knob, exposed for sweeps).
fn dataset_options_of(args: &Args) -> Result<DatasetOptions> {
    Ok(DatasetOptions::new()
        .cache_chunks(args.num("cache-chunks", DEFAULT_DATASET_CACHE_CHUNKS)?))
}

fn cmd_decompress_dataset(args: &Args) -> Result<()> {
    let input = PathBuf::from(args.req("in")?);
    let out = PathBuf::from(args.req("out")?);
    let cfg = config_of(args)?;
    let engine = session_of(args, &cfg)?;
    let opts = dataset_options_of(args)?;
    let t = std::time::Instant::now();
    let names = coordinator::decompress_dataset_file(&input, &out, &engine, &opts)?;
    println!(
        "{} -> {} ({} quantities: {}) ({:.3}s, {} threads)",
        input.display(),
        out.display(),
        names.len(),
        names.join(","),
        t.elapsed().as_secs_f64(),
        engine.threads(),
    );
    Ok(())
}

fn cmd_recompress(args: &Args) -> Result<()> {
    let input = PathBuf::from(args.req("in")?);
    let out = PathBuf::from(args.req("out")?);
    let cfg = config_of(args)?;
    let engine = engine_of(args)?;
    let st = coordinator::recompress_file(&input, &out, &cfg, engine.as_ref())?;
    println!("recompressed -> {} CR {:.2}", out.display(), st.ratio());
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let input = PathBuf::from(args.req("in")?);
    // sniff the magic without pulling the file in: .czs archives open
    // lazily (trailer + header-prefix reads only), .czm manifests are
    // tiny, .czb files still load fully below
    let head = {
        use std::io::Read;
        let mut head = [0u8; 4];
        // too-short files just fail the magic comparisons below
        let _ = std::fs::File::open(&input)?.read_exact(&mut head);
        head
    };
    if &head == distrib::CZM_MAGIC {
        let m = distrib::Manifest::open(&input).map_err(|e| anyhow!(e))?;
        let dir = input.parent().map(|p| p.to_path_buf()).unwrap_or_default();
        println!("file        : {} (czm shard manifest v{})", input.display(), distrib::CZM_VERSION);
        println!("shards      : {}", m.shards.len());
        for (i, s) in m.shards.iter().enumerate() {
            let state = if dir.join(&s.path).is_file() { "present" } else { "MISSING" };
            println!(
                "  shard {i}: {}  {} bytes  crc {:08x}  [{state}]",
                s.path, s.file_len, s.file_crc
            );
        }
        println!("quantities  : {}", m.quantities.len());
        for q in &m.quantities {
            println!("  {:>8}: {}x{}x{}  shard {}", q.name, q.nx, q.ny, q.nz, q.shard);
        }
        println!("(shard-verify walks the shard files; shard-decompress gathers them)");
        return Ok(());
    }
    if &head == cubismz::pipeline::dataset::CZS_MAGIC {
        let ds = dataset_options_of(args)?.open(&input).map_err(|e| anyhow!(e))?;
        println!("file        : {} (czs dataset archive)", input.display());
        println!("quantities  : {}", ds.entries().len());
        let mut raw_total = 0u64;
        let mut comp_total = 0u64;
        for e in ds.entries() {
            let q = ds.quantity_header(&e.name).map_err(|e| anyhow!(e))?;
            let raw = q.nx as u64 * q.ny as u64 * q.nz as u64 * 4;
            println!(
                "  {:>8}: {}x{}x{} (block {})  stage1 {:?}  stage2 {}  shuffle {:?}  {} bytes  CR {:.2}",
                e.name,
                q.nx,
                q.ny,
                q.nz,
                q.bs,
                q.stage1,
                q.stage2.name(),
                q.shuffle,
                e.len,
                raw as f64 / e.len.max(1) as f64,
            );
            // per-quantity quality metadata straight from the v3 trailer
            // — no section bytes are touched
            if let Some(aq) = &e.quality {
                println!(
                    "            bound {}  achieved max-rel {:.3e}  psnr {:.1} dB",
                    e.bound.describe(),
                    aq.max_rel_err,
                    aq.psnr_db
                );
            }
            raw_total += raw;
            comp_total += e.len;
        }
        println!("total CR    : {:.2}", raw_total as f64 / comp_total.max(1) as f64);
        println!(
            "resident    : {} of {} archive bytes loaded (lazy section reads)",
            ds.resident_bytes(),
            ds.archive_bytes()
        );
        println!("host simd   : {} (CZB_SIMD to override)", cubismz::simd::level().name());
        return Ok(());
    }
    let bytes = std::fs::read(&input)?;
    let (f, hdr) = CzbFile::parse_header(&bytes).map_err(|e| anyhow!(e))?;
    println!("file        : {}", input.display());
    println!("dataset     : {}", f.name);
    println!("dims        : {}x{}x{} (block {})", f.nx, f.ny, f.nz, f.bs);
    println!("stage1      : {:?}", f.stage1);
    println!("stage2      : {}", f.stage2.name());
    println!("shuffle     : {:?}", f.shuffle);
    if f.frame_raw > 0 {
        println!("format      : v{} (framed, {} raw bytes/frame)", f.version, f.frame_raw);
    } else {
        println!("format      : v{} (legacy unframed)", f.version);
    }
    println!("range       : [{}, {}]", f.global_min, f.global_max);
    println!("bound       : {}", f.bound.describe());
    if let Some(q) = f.achieved_quality() {
        println!(
            "achieved    : max-abs {:.3e}  max-rel {:.3e}  psnr {:.1} dB ({})",
            q.max_abs_err,
            q.max_rel_err,
            q.psnr_db,
            match f.bound.check(&q) {
                Ok(()) => "within contract".to_string(),
                Err(e) => format!("VIOLATED: {e}"),
            },
        );
    }
    println!("blocks      : {}  chunks: {}", f.nblocks, f.chunks.len());
    let payload: u64 = f.chunks.iter().map(|c| c.csize as u64).sum();
    let raw = f.nx as u64 * f.ny as u64 * f.nz as u64 * 4;
    println!("size        : {} bytes (header {hdr})", bytes.len());
    println!("CR          : {:.2}", raw as f64 / (payload + hdr as u64) as f64);
    println!("host simd   : {} (CZB_SIMD to override)", cubismz::simd::level().name());
    Ok(())
}

/// `czb verify`: walk every checksum of a `.czb`/`.czs` file without
/// writing anything; `--deep` additionally decodes each quantity and
/// reports CR + idempotence PSNR. Exit 0 = clean, 3 = corrupt content,
/// 1 = unreadable file, 2 = usage error.
fn cmd_verify(args: &Args) -> Result<()> {
    let input = PathBuf::from(args.req("in")?);
    let deep = args.flag("deep");
    let bounds = args.flag("bounds");
    let mut cfg = config_of(args)?;
    cfg.nthreads = threads_of(args, 0)?;
    let engine = session_of(args, &cfg)?;
    let t = std::time::Instant::now();
    let report = coordinator::verify_file(&input, deep, &engine)?;
    for e in &report.entries {
        match &e.outcome {
            Ok(r) if r.is_clean() => {
                let mut extra = String::new();
                if let Some(cr) = e.compression_ratio {
                    extra.push_str(&format!("  CR {cr:.2}"));
                }
                if let Some(p) = e.psnr_db {
                    extra.push_str(&format!("  idempotence PSNR {p:.1} dB"));
                }
                println!("  {:>8}: ok ({} chunks{extra})", e.name, r.total_chunks);
            }
            Ok(r) => {
                println!(
                    "  {:>8}: CORRUPT ({}/{} chunks bad, {} blocks affected)",
                    e.name,
                    r.corrupt_chunks.len(),
                    r.total_chunks,
                    r.lost_blocks
                );
                for (idx, why) in &r.corrupt_chunks {
                    println!("           chunk {idx}: {why}");
                }
            }
            Err(why) => println!("  {:>8}: CORRUPT ({why})", e.name),
        }
        if let Some(q) = &e.achieved {
            match e.bound_violation() {
                None => println!(
                    "           contract {}  achieved max-rel {:.3e}  psnr {:.1} dB",
                    e.bound.describe(),
                    q.max_rel_err,
                    q.psnr_db
                ),
                Some(why) => println!("           BOUND VIOLATED: {why}"),
            }
        } else if let Some(why) = e.bound_violation() {
            println!("           BOUND VIOLATED: {why}");
        }
    }
    let violations = report.bound_violations();
    let violated = bounds && !violations.is_empty();
    println!(
        "{}: {} ({} quantities, {}{:.3}s)",
        input.display(),
        if !report.is_clean() {
            "CORRUPT"
        } else if violated {
            "BOUND VIOLATED"
        } else {
            "clean"
        },
        report.entries.len(),
        if deep { "deep, " } else { "" },
        t.elapsed().as_secs_f64(),
    );
    if !report.is_clean() || violated {
        std::process::exit(3);
    }
    Ok(())
}

fn cmd_codecs() -> Result<()> {
    println!("registered stage-1 codecs (--scheme; `honors` lists the error-bound kinds the");
    println!("encoder guarantees — --abs-err/--rel-err/--psnr/--lossless map onto the knob):");
    for c in cubismz::pipeline::stage1::REGISTRY {
        let honored: Vec<&str> = BoundKind::ALL
            .iter()
            .filter(|k| c.honors(**k))
            .map(|k| k.name())
            .collect();
        println!(
            "  {:>9}  id {}  knob {:<12}  honors: {}",
            c.name(),
            c.id(),
            c.knob(),
            honored.join(", "),
        );
    }
    println!();
    println!("registered stage-2 codecs (--stage2 accepts any name or alias, case-insensitive):");
    for c in cubismz::codec::stage2::REGISTRY {
        let aliases = c.aliases().join(", ");
        println!(
            "  {:>9}  id {}  effort {:<8}  aliases: {}",
            c.name(),
            c.id(),
            format!("{:?}", c.effort()),
            if aliases.is_empty() { "-".to_string() } else { aliases },
        );
    }
    Ok(())
}

/// The knob ladder `czb tune` probes per codec, as multiples of the
/// contract's mapped knob. Factor 1.0 is the plain conservative mapping
/// — always within the bound by the honors contract — so the tuned
/// pick can never be worse than the untuned default; larger factors
/// exploit the slack between a codec's guaranteed worst case and its
/// measured error on the probe field.
const TUNE_LADDER: [f64; 5] = [1.0, 1.5, 2.0, 4.0, 8.0];

/// Loosen `bound` by `factor` in knob space (`None` when the kind has no
/// knob to scale or the loosened value would leave the valid range).
fn loosened_bound(bound: &Bound, factor: f64) -> Option<Bound> {
    match *bound {
        Bound::None => None,
        Bound::Lossless => (factor == 1.0).then_some(Bound::Lossless),
        Bound::Abs(a) => Some(Bound::Abs(a * factor)),
        Bound::Rel(r) => Some(Bound::Rel(r * factor)),
        Bound::Psnr(p) => {
            // the rel knob is 10^(-p/20): scaling it by `factor` lowers
            // the stated PSNR by 20*log10(factor) dB
            let q = p - 20.0 * factor.log10();
            (q > 0.0).then_some(Bound::Psnr(q))
        }
    }
}

/// Stage-1 parameter template per registry codec for the tuner; knob
/// values are placeholders that `apply_bound` resolves.
fn tune_template(id: u8) -> Option<Stage1> {
    match id {
        0 => Some(Stage1::Copy),
        2 => Some(Stage1::Zfp { tol_rel: 0.0 }),
        3 => Some(Stage1::Sz { eb_rel: 0.0 }),
        4 => Some(Stage1::Fpzip { prec: 32 }),
        // the wavelet scheme declares no bound guarantees; anything else
        // is a future codec the tuner doesn't know a template for
        _ => None,
    }
}

/// `czb tune`: sweep the stage-1 codec registry × a knob ladder against
/// a synthetic probe field per quantity, measure the *achieved* quality
/// of every candidate, and report the max-CR configuration that still
/// meets the stated contract.
fn cmd_tune(args: &Args) -> Result<()> {
    let bound = bound_of(args)?;
    if bound == Bound::None {
        return Err(anyhow!(
            "czb tune needs a contract: --abs-err T | --rel-err T | --psnr DB | --lossless"
        ));
    }
    let n: usize = args.num("size", 64)?;
    let step: usize = args.num("step", 5000)?;
    let bs: usize = args.num("bs", 32)?;
    let stage2_name = args.get("stage2").unwrap_or("zlib");
    let stage2 = Codec::from_name(stage2_name)
        .ok_or_else(|| anyhow!("unknown stage2 codec {stage2_name}"))?;
    let shuffle = shuffle_of(args)?;
    let engine = Engine::builder()
        .threads(threads_of(args, 0)?)
        .chunk_bytes(args.num("chunk-bytes", 4usize << 20)?)
        .frame_bytes(args.num("frame-bytes", cubismz::pipeline::DEFAULT_FRAME_BYTES)?)
        .wavelet_engine(engine_of(args)?)
        .build();
    let sim = CloudSim::new(CloudConfig::paper(n));
    let t0 = step_to_time(step);
    let only: Option<Vec<&str>> =
        args.get("qoi").map(|s| s.split(',').map(str::trim).collect());
    if let Some(o) = &only {
        for name in o {
            if Qoi::from_name(name).is_none() {
                return Err(anyhow!("unknown qoi {name}"));
            }
        }
    }
    println!(
        "tuning for {} on a {n}^3 step-{step} probe field (bs {bs}, stage2 {}, shuffle {:?}):",
        bound.describe(),
        stage2.name(),
        shuffle,
    );
    let mut missed_all = Vec::new();
    for qoi in Qoi::ALL {
        if let Some(o) = &only {
            if !o.contains(&qoi.name()) {
                continue;
            }
        }
        let field = sim.field(qoi, t0);
        // best = (codec name, resolved stage-1 params, achieved)
        let mut best: Option<(&'static str, Stage1, AchievedQuality)> = None;
        let mut probes = 0usize;
        for codec in cubismz::pipeline::stage1::REGISTRY {
            if !codec.honors(bound.kind()) {
                continue;
            }
            let Some(template) = tune_template(codec.id()) else { continue };
            for factor in TUNE_LADDER {
                let Some(probe) = loosened_bound(&bound, factor) else { continue };
                let params = CompressParams::new(bs, template, stage2)
                    .with_shuffle(shuffle)
                    .with_bound(probe);
                let (bytes, stats) = engine.compress_vec(&field, qoi.name(), &params);
                probes += 1;
                // judge the MEASURED quality against the ORIGINAL
                // contract: a loosened knob only wins if the probe field
                // stays inside the user's bound
                if bound.check(&stats.quality).is_err() {
                    continue;
                }
                let keep = match &best {
                    None => true,
                    Some((.., q)) => stats.quality.ratio > q.ratio,
                };
                if keep {
                    let (resolved, _) =
                        CzbFile::parse_header(&bytes).map_err(|e| anyhow!(e))?;
                    best = Some((codec.name(), resolved.stage1, stats.quality));
                }
            }
        }
        match best {
            Some((name, resolved, q)) => println!(
                "  {:>8}: --scheme {name}  {:?}  CR {:.2}  max-rel {:.3e}  psnr {:.1} dB  \
                 ({probes} probes)",
                qoi.name(),
                resolved,
                q.ratio,
                q.max_rel_err,
                q.psnr_db,
            ),
            None => {
                println!(
                    "  {:>8}: no registered codec met {} ({probes} probes)",
                    qoi.name(),
                    bound.describe()
                );
                missed_all.push(qoi.name());
            }
        }
    }
    if !missed_all.is_empty() {
        return Err(anyhow!("no configuration met the bound for: {}", missed_all.join(",")));
    }
    Ok(())
}

fn cmd_psnr(args: &Args) -> Result<()> {
    let reference = PathBuf::from(args.req("ref")?);
    let dataset = args.req("dataset")?;
    let input = PathBuf::from(args.req("in")?);
    let engine = engine_of(args)?;
    let p = coordinator::psnr_file(&reference, dataset, &input, engine.as_ref())?;
    println!("PSNR {p:.2} dB");
    Ok(())
}

/// `czb serve`: run the long-running compression service (see
/// docs/PROTOCOL.md for the wire protocol). Drains gracefully on
/// SIGTERM/SIGINT or a client `shutdown` request.
fn cmd_serve(args: &Args) -> Result<()> {
    let cfg = service::ServeConfig {
        addr: args.get("addr").unwrap_or("127.0.0.1:9321").to_string(),
        threads: threads_of(args, 0)?,
        admit_normal: args.num("admit", 0usize)?,
        admit_high_extra: args.num("admit-high", 2usize)?,
        retry_after_ms: args.num("retry-after-ms", 100u32)?,
        quota_capacity: args.num("quota-capacity", 256u64 << 20)?,
        quota_rate: args.num("quota-rate", 0u64)?,
        max_body: args.num("max-body", service::proto::DEFAULT_MAX_BODY)?,
        ..Default::default()
    };
    let server = service::Server::bind(&cfg)?;
    let addr = server.local_addr()?;
    service::install_sigterm_drain(server.handle());
    println!(
        "czb serve: listening on {addr} (quota {}; SIGTERM or a `shutdown` frame drains)",
        if cfg.quota_rate > 0 {
            format!("{} B + {} B/s per tenant", cfg.quota_capacity, cfg.quota_rate)
        } else {
            "off".to_string()
        },
    );
    println!("czb serve: simd dispatch {} (CZB_SIMD to override)", cubismz::simd::level().name());
    server.run()?;
    println!("czb serve: drained");
    Ok(())
}

/// One refusal-aware exchange for `czb client`: refusals (busy, quota,
/// shutting_down, error) exit 4 so scripts can tell "the server said
/// no" from "the transport broke" (exit 1).
fn client_reply<T>(r: std::result::Result<service::Reply<T>, String>) -> Result<T> {
    match r {
        Ok(Ok(v)) => Ok(v),
        Ok(Err(refusal)) => {
            eprintln!("refused: {refusal}");
            std::process::exit(4);
        }
        Err(e) => Err(anyhow!(e)),
    }
}

/// `czb client`: one request against a running `czb serve`.
fn cmd_client(args: &Args) -> Result<()> {
    let addr = args.get("addr").unwrap_or("127.0.0.1:9321");
    let op = args.req("op")?;
    let mut client = service::Client::connect(addr)?;
    if let Some(t) = args.get("tenant") {
        client = client.tenant(t);
    }
    client = client.priority(match args.get("priority").unwrap_or("normal") {
        "normal" => service::proto::Priority::Normal,
        "high" => service::proto::Priority::High,
        p => return Err(anyhow!("unknown priority {p} (normal|high)")),
    });
    match op {
        "stat" => {
            print!("{}", client_reply(client.stat())?);
        }
        "shutdown" => {
            client_reply(client.shutdown())?;
            println!("server draining");
        }
        "compress" => {
            let input = PathBuf::from(args.req("in")?);
            let dataset = args.req("dataset")?;
            let out = PathBuf::from(args.req("out")?);
            let field = h5lite::read(&input, dataset).map_err(|e| anyhow!(e))?.to_field();
            let bs: u32 = args.num("bs", 32u32)?;
            let eps: f32 = args.num("eps", 1e-3f32)?;
            if !eps.is_finite() || eps < 0.0 {
                return Err(anyhow!("--eps must be finite and >= 0, got {eps}"));
            }
            let shuffle = shuffle_of(args)?;
            let bound = bound_of(args)?;
            if bound != Bound::None && args.get("eps").is_some() {
                return Err(anyhow!(
                    "--eps (raw codec knob) conflicts with an error-bound flag; \
                     state the contract alone and the knob is derived from it"
                ));
            }
            let t = std::time::Instant::now();
            let czb = client_reply(
                client.compress_bounded(dataset, &field, bs, eps, shuffle, bound),
            )?;
            std::fs::write(&out, &czb)?;
            println!(
                "{dataset}: {} -> {} bytes via {addr}  CR {:.2}  ({:.3}s)",
                field.nbytes(),
                czb.len(),
                field.nbytes() as f64 / czb.len().max(1) as f64,
                t.elapsed().as_secs_f64(),
            );
        }
        "decompress" => {
            let input = PathBuf::from(args.req("in")?);
            let out = PathBuf::from(args.req("out")?);
            let czb = std::fs::read(&input)?;
            let t = std::time::Instant::now();
            let (name, field) = client_reply(client.decompress(&czb))?;
            h5lite::write(&out, &[h5lite::Dataset::from_field(&name, &field)])?;
            println!(
                "{name} ({}x{}x{}) -> {} via {addr} ({:.3}s)",
                field.nx,
                field.ny,
                field.nz,
                out.display(),
                t.elapsed().as_secs_f64(),
            );
        }
        "verify" => {
            let input = PathBuf::from(args.req("in")?);
            let czb = std::fs::read(&input)?;
            let s = client_reply(client.verify(&czb))?;
            println!(
                "{}: {} ({} chunks, {} corrupt, {} blocks lost)",
                input.display(),
                if s.clean { "clean" } else { "CORRUPT" },
                s.total_chunks,
                s.corrupt_chunks,
                s.lost_blocks,
            );
            if !s.clean {
                std::process::exit(3);
            }
        }
        o => return Err(anyhow!("unknown op {o} (compress|decompress|verify|stat|shutdown)")),
    }
    Ok(())
}

/// `czb shard-compress`: distribute a dataset's quantities across N
/// workers (spawned local `czb serve` processes or running endpoints)
/// into per-shard `.czs` files plus a `.czm` manifest.
fn cmd_shard_compress(args: &Args) -> Result<()> {
    let input = PathBuf::from(args.req("in")?);
    let out = PathBuf::from(args.req("out")?);
    let bs: u32 = args.num("bs", 32u32)?;
    let eps: f32 = args.num("eps", 1e-3f32)?;
    if !eps.is_finite() || eps < 0.0 {
        return Err(anyhow!("--eps must be finite and >= 0, got {eps}"));
    }
    let shuffle = shuffle_of(args)?;
    let bound = bound_of(args)?;
    if bound != Bound::None && args.get("eps").is_some() {
        return Err(anyhow!(
            "--eps (raw codec knob) conflicts with an error-bound flag; \
             state the contract alone and the knob is derived from it"
        ));
    }
    let workers = match args.get("endpoints") {
        Some(list) => {
            if args.get("shards").is_some() {
                return Err(anyhow!(
                    "--shards conflicts with --endpoints (one shard per endpoint)"
                ));
            }
            let endpoints: Vec<String> = list
                .split(',')
                .map(str::trim)
                .filter(|s| !s.is_empty())
                .map(String::from)
                .collect();
            if endpoints.is_empty() {
                return Err(anyhow!("--endpoints is empty"));
            }
            distrib::WorkerSet::Endpoints(endpoints)
        }
        None => distrib::WorkerSet::Spawn {
            exe: std::env::current_exe()?,
            count: args.num("shards", 2usize)?,
            threads: args.num("worker-threads", 0usize)?,
        },
    };
    let opts = distrib::ShardOptions { bs, eps, shuffle, bound };
    let t = std::time::Instant::now();
    let stats = distrib::shard_compress(&input, args.get("qoi"), &out, &workers, &opts)?;
    let (mut raw, mut comp) = (0u64, 0u64);
    for (i, st) in stats.iter().enumerate() {
        println!(
            "  shard {i}: {}  [{}]  {} -> {} bytes  CR {:.2}  via {}",
            st.path,
            st.quantities.join(","),
            st.raw_bytes,
            st.compressed_bytes,
            st.ratio(),
            st.endpoint,
        );
        raw += st.raw_bytes;
        comp += st.compressed_bytes;
    }
    println!(
        "{} shards -> {}  CR {:.2}  ({:.3}s)",
        stats.len(),
        out.display(),
        raw as f64 / comp.max(1) as f64,
        t.elapsed().as_secs_f64(),
    );
    Ok(())
}

/// `czb shard-decompress`: gather every shard of a `.czm` manifest back
/// into one h5lite container with per-shard fault isolation — a lost or
/// corrupt shard zero-fills its quantities (exit 3) while the rest
/// decode intact, mirroring `czb decompress --salvage`.
fn cmd_shard_decompress(args: &Args) -> Result<()> {
    let input = PathBuf::from(args.req("in")?);
    let out = PathBuf::from(args.req("out")?);
    let engine = Engine::builder()
        .threads(threads_of(args, 0)?)
        .wavelet_engine(engine_of(args)?)
        .build();
    let opts = dataset_options_of(args)?;
    let t = std::time::Instant::now();
    let decodes = distrib::shard_decompress(&input, &out, &engine, &opts)?;
    let mut damaged = false;
    for d in &decodes {
        match &d.report {
            Ok(rep) if rep.is_clean() => {
                println!("  {:>8}: clean ({} chunks, shard {})", d.name, rep.total_chunks, d.shard);
            }
            Ok(rep) => {
                damaged = true;
                println!(
                    "  {:>8}: salvaged {}/{} chunks ({} blocks zero-filled, shard {})",
                    d.name,
                    rep.salvaged_chunks(),
                    rep.total_chunks,
                    rep.lost_blocks,
                    d.shard,
                );
                for (idx, why) in &rep.corrupt_chunks {
                    println!("           chunk {idx}: {why}");
                }
            }
            Err(e) => {
                damaged = true;
                println!("  {:>8}: LOST (zero-filled, shard {}): {e}", d.name, d.shard);
            }
        }
    }
    println!(
        "{} -> {} ({} quantities, {:.3}s, {} threads)",
        input.display(),
        out.display(),
        decodes.len(),
        t.elapsed().as_secs_f64(),
        engine.threads(),
    );
    if damaged {
        std::process::exit(3);
    }
    Ok(())
}

/// `czb shard-verify`: check a sharded dataset without writing anything
/// — manifest CRC, per-shard file length + whole-file CRC32C, each
/// shard's own checksum walk (`--deep` fully decodes), and
/// manifest<->shard consistency. Exit 0 clean, 3 anything wrong.
fn cmd_shard_verify(args: &Args) -> Result<()> {
    let input = PathBuf::from(args.req("in")?);
    let deep = args.flag("deep");
    let engine = Engine::builder()
        .threads(threads_of(args, 0)?)
        .wavelet_engine(engine_of(args)?)
        .build();
    let t = std::time::Instant::now();
    let report = distrib::shard_verify(&input, deep, &engine)?;
    for e in &report.entries {
        match &e.file {
            Ok(()) => println!("  shard {}: file ok", e.path),
            Err(why) => println!("  shard {}: FILE BAD ({why})", e.path),
        }
        if let Some(r) = &e.sections {
            for s in &r.entries {
                match &s.outcome {
                    Ok(rep) if rep.is_clean() => {
                        println!("    {:>8}: ok ({} chunks)", s.name, rep.total_chunks);
                    }
                    Ok(rep) => println!(
                        "    {:>8}: CORRUPT ({}/{} chunks bad)",
                        s.name,
                        rep.corrupt_chunks.len(),
                        rep.total_chunks
                    ),
                    Err(why) => println!("    {:>8}: CORRUPT ({why})", s.name),
                }
            }
        }
        for m in &e.mapping {
            println!("    MANIFEST MISMATCH: {m}");
        }
    }
    println!(
        "{}: {} ({} shards, {}{:.3}s)",
        input.display(),
        if report.is_clean() { "clean" } else { "CORRUPT" },
        report.entries.len(),
        if deep { "deep, " } else { "" },
        t.elapsed().as_secs_f64(),
    );
    if !report.is_clean() {
        std::process::exit(3);
    }
    Ok(())
}

/// Every registered subcommand, in dispatch order. The flag registry,
/// the dispatch match and the usage text are all checked against this
/// list (unit test below plus tests/cli_integration.rs), so a new
/// subcommand cannot ship half-wired.
const COMMANDS: &[&str] = &[
    "gen",
    "compress",
    "decompress",
    "recompress",
    "compress-dataset",
    "decompress-dataset",
    "shard-compress",
    "shard-decompress",
    "shard-verify",
    "verify",
    "tune",
    "codecs",
    "info",
    "psnr",
    "serve",
    "client",
    "help",
];

const USAGE_BODY: &str = "czb — CubismZ-RS parallel compression tool
USAGE: czb <command> [flags]
  gen         --size N --step S --out f.h5l [--bubbles K] [--production] [--qoi p|rho|E|a2]
  compress    --in f.h5l --dataset NAME --out f.czb [--scheme wavelet|zfp|sz|fpzip|copy]
              [--abs-err T | --rel-err T | --psnr DB | --lossless]
              (an error-bound contract: the stage-1 knob is derived from it, and the
               contract + achieved quality are recorded in the stream for verify --bounds;
               with no --scheme the codec is auto-picked, an explicit --scheme must
               honor the bound kind — see `czb codecs`)
              [--wavelet w4|w4l|w3a] [--eps 1e-3 (legacy raw knob; excludes bound flags)]
              [--prec 24] [--zbits N] [--coeff none|fpzip|sz|spdp]
              [--stage2 zlib|zlib-def|zlib-best|lz4|zstd|lzma|none (case-insensitive, see codecs)]
              [--shuffle [none|byte4|bit4]] [--bs 32] [--chunk-bytes N] [--frame-bytes N (0 = default 256Ki)]
              [--threads N (0 = all cores)] [--engine native|pjrt]
              (--dataset p,rho,E compresses every stream concurrently on one engine
               into --out DIR/<name>.czb; [--jobs N] caps the streams in flight, 0 = all;
               a comma in --dataset always separates streams)
  decompress  --in f.czb --out f.h5l [--engine native|pjrt] [--threads N (0 = all cores)]
              (--in a.czb,b.czb decompresses the streams concurrently on one engine
               into --out DIR/<stem>.h5l; [--jobs N] as above)
              [--salvage: decode every intact chunk of a damaged .czb or .czs,
               zero-fill corrupt chunks and list them; exit 3 if anything was lost]
  recompress  --in f.czb --out g.czb [same flags as compress]
  compress-dataset    --in f.h5l --out f.czs [--qoi p,rho] [same scheme flags as compress]
                      (all quantities through one Engine session into one .czs archive,
                       written via a temp file so failures leave no partial archive)
  decompress-dataset  --in f.czs --out f.h5l [--threads N] [--engine native|pjrt]
                      [--cache-chunks N (shared decoded-chunk cache size, default 32)]
                      (lazy section reads; quantities decode concurrently on one pool)
  shard-compress      --in f.h5l --out f.czm [--qoi p,rho] [--shards N (default 2)]
                      [--worker-threads N (per spawned worker, 0 = all cores)]
                      [--endpoints HOST:PORT,HOST:PORT (use running `czb serve` workers
                       instead of spawning local ones; one shard per endpoint)]
                      [--bs 32] [--eps 1e-3] [--shuffle [none|byte4|bit4]]
                      [--abs-err T | --rel-err T | --psnr DB | --lossless]
                      (distribute quantities across N workers over the service protocol
                       into <stem>.shard<i>.czs files plus a .czm manifest; sections are
                       bit-identical to compress-dataset --stage2 zlib-def; see
                       docs/FORMATS.md for the manifest layout)
  shard-decompress    --in f.czm --out f.h5l [--threads N] [--engine native|pjrt]
                      [--cache-chunks N]
                      (gather every shard back into one container with per-shard fault
                       isolation: a lost or corrupt shard zero-fills its quantities and
                       exits 3 while the rest decode intact)
  shard-verify        --in f.czm [--deep] [--threads N] [--engine native|pjrt]
                      (manifest CRC, per-shard file length + whole-file CRC32C, each
                       shard's full checksum walk — --deep fully decodes — and
                       manifest<->shard consistency; exit 0 clean, 3 corrupt/missing)
  verify      --in f.czb|f.czs [--deep] [--bounds] [--threads N] [--engine native|pjrt]
              (walk every checksum — v4 header digest, per-chunk CRC32C, czs section
               digests — without decoding; --deep fully decodes each quantity and
               reports CR + idempotence PSNR; --bounds additionally checks every
               recorded error-bound contract against the achieved quality and exits 3
               on any violation)
              exit codes: 0 clean, 3 corrupt content or violated bound, 1 unreadable
              file, 2 usage
  tune        --abs-err T | --rel-err T | --psnr DB | --lossless
              [--size 64] [--step 5000] [--qoi p,rho] [--stage2 zlib] [--bs 32]
              [--shuffle MODE] [--threads N] [--engine native|pjrt]
              (sweep the stage-1 codec registry and a knob ladder on a synthetic probe
               field per quantity; print the max-CR configuration whose measured
               quality still meets the contract)
  codecs      (list the registered stage-1 codecs with their native knob and honored
               bound kinds, plus the stage-2 codecs, ids, efforts and aliases)
  info        --in f.czb | f.czs | f.czm  [--cache-chunks N]  (czs archives open lazily;
               czm manifests list shards, quantities and shard-file presence)
  psnr        --ref f.h5l --dataset NAME --in f.czb
  serve       [--addr 127.0.0.1:9321] [--threads N (0 = all cores)]
              [--admit N (in-flight requests, 0 = 2x threads)] [--admit-high N (extra
               high-priority slots)] [--retry-after-ms MS] [--max-body BYTES]
              [--quota-capacity BYTES] [--quota-rate BYTES/S (0 = quotas off)]
              (long-running compression service: length-prefixed binary frames over
               TCP — compress/decompress/verify/stat/shutdown — one shared engine
               pool for all connections; overload answers busy/quota + retry-after
               instead of queueing; SIGTERM or a shutdown frame drains gracefully;
               wire format in docs/PROTOCOL.md)
  client      --op compress|decompress|verify|stat|shutdown [--addr HOST:PORT]
              [--tenant ID] [--priority normal|high]
              (compress:   --in f.h5l --dataset NAME --out f.czb [--eps 1e-3]
                           [--abs-err T | --rel-err T | --psnr DB | --lossless]
                           [--bs 32] [--shuffle [none|byte4|bit4]])
              (decompress: --in f.czb --out f.h5l)   (verify: --in f.czb)
              exit codes: 0 ok, 3 verify found corruption, 4 server refused
              (busy/quota/draining/error), 1 transport failure, 2 usage
  help        (print this usage on stdout and exit 0)

Unknown flags after a subcommand are a usage error (exit 2).";

/// The full usage text: the body plus a machine-checkable `commands:`
/// line enumerating every registered subcommand.
fn usage_text() -> String {
    format!("{USAGE_BODY}\ncommands: {}\n", COMMANDS.join(" "))
}

fn usage() -> ! {
    eprint!("{}", usage_text());
    std::process::exit(2);
}

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        usage();
    }
    let cmd = argv[0].clone();
    let args = match Args::parse(&argv[1..]) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            usage();
        }
    };
    match allowed_flags(cmd.as_str()) {
        None => {
            eprintln!("unknown command {cmd}");
            usage();
        }
        Some(allowed) => {
            if let Err(e) = args.check_known(&cmd, &allowed) {
                eprintln!("error: {e}");
                usage();
            }
        }
    }
    let r = match cmd.as_str() {
        "gen" => cmd_gen(&args),
        "compress" => cmd_compress(&args),
        "decompress" => cmd_decompress(&args),
        "recompress" => cmd_recompress(&args),
        "compress-dataset" => cmd_compress_dataset(&args),
        "decompress-dataset" => cmd_decompress_dataset(&args),
        "shard-compress" => cmd_shard_compress(&args),
        "shard-decompress" => cmd_shard_decompress(&args),
        "shard-verify" => cmd_shard_verify(&args),
        "verify" => cmd_verify(&args),
        "tune" => cmd_tune(&args),
        "codecs" => cmd_codecs(),
        "help" => {
            print!("{}", usage_text());
            Ok(())
        }
        "info" => cmd_info(&args),
        "psnr" => cmd_psnr(&args),
        "serve" => cmd_serve(&args),
        "client" => cmd_client(&args),
        // allowed_flags() already rejected unknown commands
        _ => unreachable!("command {cmd} has a flag list but no dispatch arm"),
    };
    if let Err(e) = r {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_command_is_fully_wired() {
        for cmd in COMMANDS {
            assert!(allowed_flags(cmd).is_some(), "{cmd} is not in the flag registry");
            assert!(USAGE_BODY.contains(cmd), "{cmd} is not documented in the usage text");
        }
        assert!(allowed_flags("no-such-command").is_none());
        // the machine-checkable commands line really enumerates them all
        let text = usage_text();
        let line = text
            .lines()
            .find(|l| l.starts_with("commands: "))
            .expect("usage_text carries a commands: line");
        for cmd in COMMANDS {
            assert!(
                line.split_whitespace().any(|w| w == *cmd),
                "{cmd} missing from the commands: line"
            );
        }
    }
}
