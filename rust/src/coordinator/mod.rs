//! Coordinator (the L3 entry points): ex-situ tool operations over files
//! and the in-situ hook API a simulation embeds (paper §2: "When coupled
//! with simulation software ... CubismZ serves as a module for in situ
//! data compression").
use crate::anyhow;
use crate::cluster::Comm;
use crate::core::Field3;
use crate::io::{h5lite, parallel};
use crate::metrics::{compression_ratio, psnr};
use crate::pipeline::{
    compress_field, decompress_field_mt, verify_stream, AchievedQuality, Bound, CompressParams,
    CompressStats, Dataset, DatasetOptions, DecodeReport, Engine, PipelineConfig, WaveletEngine,
};
use crate::util::error::{Context, Result};
use std::path::{Path, PathBuf};

/// Ex-situ: read a dataset from an h5lite container, compress it, write
/// the `.czb` file. Returns the stats.
pub fn compress_file(
    input: &Path,
    dataset: &str,
    output: &Path,
    cfg: &PipelineConfig,
    engine: &dyn WaveletEngine,
) -> Result<CompressStats> {
    let ds = h5lite::read(input, dataset).map_err(|e| anyhow!(e))?;
    let field = ds.to_field();
    let (bytes, stats) = compress_field(&field, dataset, cfg, engine);
    std::fs::write(output, &bytes).with_context(|| format!("writing {}", output.display()))?;
    Ok(stats)
}

/// Ex-situ: decompress a `.czb` file back into an h5lite container
/// (paper: "they can be converted to HDF5 format and visualized").
/// Whole-field decompression runs chunk-parallel over `nthreads` workers
/// (paper §2.3 "parallel decompression").
pub fn decompress_file(
    input: &Path,
    output: &Path,
    engine: &dyn WaveletEngine,
    nthreads: usize,
) -> Result<(String, Field3)> {
    let bytes = std::fs::read(input).with_context(|| format!("reading {}", input.display()))?;
    let (field, file) = decompress_field_mt(&bytes, engine, nthreads).map_err(|e| anyhow!(e))?;
    h5lite::write(output, &[h5lite::Dataset::from_field(&file.name, &field)])?;
    Ok((file.name, field))
}

/// Recompress a `.czb` with a different configuration (paper: compressed
/// files can be "recompressed using any of the supported methods").
pub fn recompress_file(
    input: &Path,
    output: &Path,
    cfg: &PipelineConfig,
    engine: &dyn WaveletEngine,
) -> Result<CompressStats> {
    let bytes = std::fs::read(input)?;
    let (field, file) = decompress_field_mt(&bytes, engine, cfg.nthreads).map_err(|e| anyhow!(e))?;
    let (out, stats) = compress_field(&field, &file.name, cfg, engine);
    std::fs::write(output, &out)?;
    Ok(stats)
}

/// PSNR between a reference h5lite dataset and a compressed `.czb`.
pub fn psnr_file(
    reference: &Path,
    dataset: &str,
    compressed: &Path,
    engine: &dyn WaveletEngine,
) -> Result<f64> {
    let r = h5lite::read(reference, dataset).map_err(|e| anyhow!(e))?;
    let bytes = std::fs::read(compressed)?;
    let (d, _) = decompress_field_mt(&bytes, engine, 1).map_err(|e| anyhow!(e))?;
    if d.data.len() != r.data.len() {
        return Err(anyhow!("size mismatch: {} vs {}", d.data.len(), r.data.len()));
    }
    psnr(&r.data, &d.data)
        .ok_or_else(|| anyhow!("psnr undefined (empty or non-finite reference)"))
}

/// One quantity's outcome in a [`VerifyReport`].
#[derive(Clone, Debug)]
pub struct VerifyEntry {
    pub name: String,
    /// `Ok` — the stream was walked; the report lists any chunks whose
    /// checksum failed. `Err` — the quantity could not be walked at all
    /// (header digest, section digest, or index damage); it counts as
    /// corrupt, not unreadable, because the *file* itself was fine.
    pub outcome: std::result::Result<DecodeReport, String>,
    /// Deep mode only: raw bytes / compressed bytes of the full decode.
    pub compression_ratio: Option<f64>,
    /// Deep mode only: idempotence PSNR — the decoded field re-encoded
    /// with the archive's own stage-1/stage-2/shuffle parameters and
    /// decoded again, then compared against the first decode. The
    /// original field is gone, so this is a self-consistency figure
    /// (near-infinite when the codec is healthy), not fidelity to the
    /// simulation.
    pub psnr_db: Option<f64>,
    /// Error-bound contract recorded in the stream's own header
    /// ([`Bound::None`] on v≤4 streams, which predate contracts).
    pub bound: Bound,
    /// Achieved-quality summary folded from the stream's recorded
    /// per-chunk column; `None` on v≤4 streams.
    pub achieved: Option<AchievedQuality>,
}

impl VerifyEntry {
    pub fn is_clean(&self) -> bool {
        matches!(&self.outcome, Ok(r) if r.is_clean())
    }

    /// `Some(reason)` when the recorded achieved quality breaks the
    /// recorded contract (what `czb verify --bounds` turns into exit 3).
    /// A contract with no recorded quality is itself a violation — it
    /// can only arise from a tampered or truncated-and-rebuilt stream.
    pub fn bound_violation(&self) -> Option<String> {
        match (&self.bound, &self.achieved) {
            (Bound::None, _) => None,
            (b, Some(q)) => b.check(q).err(),
            (b, None) => {
                Some(format!("stream declares `{}` but records no quality", b.describe()))
            }
        }
    }
}

/// What [`verify_file`] found, one entry per quantity (a bare `.czb`
/// verifies as a single-quantity file). The CLI maps this to exit
/// codes: 0 when [`VerifyReport::is_clean`], 3 otherwise; failures to
/// read or parse the file at all surface as this function's `Err` and
/// exit 1.
#[derive(Clone, Debug)]
pub struct VerifyReport {
    pub entries: Vec<VerifyEntry>,
}

impl VerifyReport {
    pub fn is_clean(&self) -> bool {
        self.entries.iter().all(VerifyEntry::is_clean)
    }

    /// Names of quantities that failed verification.
    pub fn corrupt(&self) -> Vec<&str> {
        self.entries.iter().filter(|e| !e.is_clean()).map(|e| e.name.as_str()).collect()
    }

    /// Quantities whose recorded quality violates their recorded
    /// contract, with the reason.
    pub fn bound_violations(&self) -> Vec<(&str, String)> {
        self.entries
            .iter()
            .filter_map(|e| e.bound_violation().map(|v| (e.name.as_str(), v)))
            .collect()
    }
}

/// Deep-verify one section: full decode, then CR and the idempotence
/// PSNR described on [`VerifyEntry::psnr_db`].
fn deep_metrics(
    engine: &Engine,
    section: &[u8],
) -> std::result::Result<(Option<f64>, Option<f64>), String> {
    let (field, file) = engine.decompress_bytes(section)?;
    let cr = compression_ratio(field.nbytes(), section.len());
    // re-encode with the archive's own parameters; the knob already
    // encodes whatever contract produced it, so no bound is re-applied
    let params = CompressParams {
        bs: file.bs as usize,
        stage1: file.stage1,
        stage2: file.stage2,
        shuffle: file.shuffle,
        bound: Bound::None,
    };
    let (again_bytes, _) = engine.compress_vec(&field, &file.name, &params);
    let (again, _) = engine.decompress_bytes(&again_bytes)?;
    Ok((cr, psnr(&field.data, &again.data)))
}

/// Verify one in-memory `.czb` stream (a single quantity): the same
/// checksum walk — and optional deep decode — as [`verify_file`]'s
/// `.czb` branch, shared with the service front-end's `verify`
/// request, which receives its stream over a socket rather than from
/// a path.
pub fn verify_czb_bytes(bytes: &[u8], deep: bool, engine: &Engine) -> VerifyEntry {
    let (name, bound, achieved) = match crate::pipeline::CzbFile::parse_header(bytes) {
        Ok((f, _)) => {
            let q = f.achieved_quality();
            (f.name, f.bound, q)
        }
        Err(_) => ("?".to_string(), Bound::None, None),
    };
    let mut outcome = verify_stream(bytes);
    let (mut cr, mut db) = (None, None);
    if deep && matches!(&outcome, Ok(r) if r.is_clean()) {
        match deep_metrics(engine, bytes) {
            Ok((c, p)) => (cr, db) = (c, p),
            Err(e) => outcome = Err(format!("deep decode: {e}")),
        }
    }
    VerifyEntry { name, outcome, compression_ratio: cr, psnr_db: db, bound, achieved }
}

/// Verify the integrity of a `.czb` or `.czs` file (sniffed by magic)
/// without writing anything.
///
/// Shallow mode walks headers, indices, and checksums — the v4 header
/// digest, per-chunk CRC32Cs, and (for archives) the per-section
/// trailer digests — without inflating a single chunk. `deep`
/// additionally decodes every quantity in full on the engine's pool and
/// records its compression ratio and idempotence PSNR.
///
/// `Err` means the file itself was unreadable (missing, truncated below
/// a header, unknown magic, unparseable trailer) — CLI exit 1. An `Ok`
/// report may still flag corrupt quantities — CLI exit 3.
pub fn verify_file(input: &Path, deep: bool, engine: &Engine) -> Result<VerifyReport> {
    let head = {
        use std::io::Read as _;
        let mut f = std::fs::File::open(input)
            .with_context(|| format!("opening {}", input.display()))?;
        let mut magic = [0u8; 4];
        f.read_exact(&mut magic)
            .with_context(|| format!("reading magic of {}", input.display()))?;
        magic
    };
    let mut entries = Vec::new();
    if &head == crate::pipeline::dataset::CZS_MAGIC {
        let archive = DatasetOptions::new().open(input).map_err(|e| anyhow!(e))?;
        for idx in 0..archive.entries().len() {
            let name = archive.entries()[idx].name.clone();
            // section_at checks the trailer digest before handing out
            // bytes; a mismatch fails the quantity as a whole (v<=3
            // inner streams have no finer-grained checksums to fall
            // back on)
            let mut outcome = archive.section_at(idx).and_then(verify_stream);
            // the section's own header is the authority on its contract
            // (the trailer copy is derived from it at write time)
            let (bound, achieved) = match archive
                .section_at(idx)
                .and_then(|s| crate::pipeline::CzbFile::parse_header(s).map(|(f, _)| f))
            {
                Ok(f) => {
                    let q = f.achieved_quality();
                    (f.bound, q)
                }
                Err(_) => (Bound::None, None),
            };
            let (mut cr, mut db) = (None, None);
            if deep && matches!(&outcome, Ok(r) if r.is_clean()) {
                match archive.section_at(idx).and_then(|s| deep_metrics(engine, s)) {
                    Ok((c, p)) => (cr, db) = (c, p),
                    Err(e) => outcome = Err(format!("deep decode: {e}")),
                }
            }
            entries.push(VerifyEntry {
                name,
                outcome,
                compression_ratio: cr,
                psnr_db: db,
                bound,
                achieved,
            });
        }
    } else if &head == crate::pipeline::format::MAGIC {
        let bytes =
            std::fs::read(input).with_context(|| format!("reading {}", input.display()))?;
        entries.push(verify_czb_bytes(&bytes, deep, engine));
    } else {
        return Err(anyhow!(
            "{}: not a .czb or .czs file (magic {:02x?})",
            input.display(),
            head
        ));
    }
    Ok(VerifyReport { entries })
}

/// Salvage-decompress a damaged `.czb` or `.czs` (sniffed by magic)
/// into an h5lite container at `output`: every intact chunk of every
/// readable quantity decodes bit-identically to a clean decode, corrupt
/// chunks come back zero-filled, and the per-quantity reports enumerate
/// exactly what was lost. A quantity whose header or index is
/// unreadable is skipped — its slot carries the error — while its
/// siblings still land in `output`. `Err` only when nothing at all was
/// salvageable (CLI exit 1).
pub fn salvage_file(
    input: &Path,
    output: &Path,
    engine: &Engine,
) -> Result<Vec<(String, std::result::Result<DecodeReport, String>)>> {
    let bytes = std::fs::read(input).with_context(|| format!("reading {}", input.display()))?;
    let mut reports = Vec::new();
    let mut datasets = Vec::new();
    if bytes.len() >= 4 && &bytes[..4] == crate::pipeline::dataset::CZS_MAGIC {
        let archive = DatasetOptions::new().open(input).map_err(|e| anyhow!(e))?;
        for (name, r) in
            engine.decompress_dataset_salvage(&archive, None).map_err(|e| anyhow!(e))?
        {
            match r {
                Ok((field, _file, rep)) => {
                    datasets.push(h5lite::Dataset::from_field(&name, &field));
                    reports.push((name, Ok(rep)));
                }
                Err(e) => reports.push((name, Err(e))),
            }
        }
    } else {
        let (field, file, rep) = engine.decompress_salvage(&bytes).map_err(|e| anyhow!(e))?;
        datasets.push(h5lite::Dataset::from_field(&file.name, &field));
        reports.push((file.name, Ok(rep)));
    }
    if datasets.is_empty() {
        return Err(anyhow!("nothing salvageable in {}", input.display()));
    }
    h5lite::write(output, &datasets)?;
    Ok(reports)
}

/// Ex-situ: compress every dataset of an h5lite container (optionally a
/// comma-separated `only` subset) into one `.czs` archive on a single
/// [`Engine`] session — the multi-QoI shape of the paper's CFD workflow.
/// Returns (name, stats) per quantity in archive order.
///
/// The archive is built at a sibling temp path and renamed into place
/// only on success: a mid-archive failure must never leave a
/// trailer-less partial `.czs` at the output path, and a failing re-run
/// must not clobber an existing good archive.
pub fn compress_dataset_file(
    input: &Path,
    only: Option<&str>,
    output: &Path,
    params: &CompressParams,
    engine: &Engine,
) -> Result<Vec<(String, CompressStats)>> {
    // unique per process AND per call: two concurrent compressions to
    // the same output must not interleave writes into one temp file
    static TMP_COUNTER: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
    let mut tmp_name = output
        .file_name()
        .map(|n| n.to_os_string())
        .unwrap_or_else(|| std::ffi::OsString::from("archive.czs"));
    tmp_name.push(format!(
        ".{}.{}.tmp",
        std::process::id(),
        TMP_COUNTER.fetch_add(1, std::sync::atomic::Ordering::Relaxed)
    ));
    let tmp_path = output.with_file_name(tmp_name);
    match compress_dataset_to(input, only, &tmp_path, params, engine) {
        Ok(stats) => match std::fs::rename(&tmp_path, output) {
            Ok(()) => Ok(stats),
            Err(e) => {
                let _ = std::fs::remove_file(&tmp_path);
                Err(anyhow!("moving {} into place: {e}", output.display()))
            }
        },
        Err(e) => {
            let _ = std::fs::remove_file(&tmp_path);
            Err(e)
        }
    }
}

fn compress_dataset_to(
    input: &Path,
    only: Option<&str>,
    output: &Path,
    params: &CompressParams,
    engine: &Engine,
) -> Result<Vec<(String, CompressStats)>> {
    let wanted: Option<Vec<&str>> = only.map(|s| s.split(',').map(str::trim).collect());
    let names = h5lite::list(input).map_err(|e| anyhow!(e))?;
    let mut writer = Dataset::create(output)
        .with_context(|| format!("creating {}", output.display()))?;
    let mut out = Vec::new();
    for (name, ..) in names {
        if let Some(w) = &wanted {
            if !w.contains(&name.as_str()) {
                continue;
            }
        }
        let ds = h5lite::read(input, &name).map_err(|e| anyhow!(e))?;
        let field = ds.to_field();
        let stats = writer
            .write_quantity(engine, &field, &name, params)
            .with_context(|| format!("writing quantity {name}"))?;
        out.push((name, stats));
    }
    if let Some(w) = &wanted {
        // a typo'd subset name must fail loudly, not silently produce an
        // archive with a quantity missing
        let missing: Vec<&str> = w
            .iter()
            .filter(|n| !out.iter().any(|(name, _)| name == *n))
            .copied()
            .collect();
        if !missing.is_empty() {
            return Err(anyhow!(
                "requested quantities not in {}: {}",
                input.display(),
                missing.join(",")
            ));
        }
    }
    if out.is_empty() {
        return Err(anyhow!("no datasets matched in {}", input.display()));
    }
    writer.finish().with_context(|| format!("finishing {}", output.display()))?;
    Ok(out)
}

/// Ex-situ: decompress every quantity of a `.czs` archive back into one
/// h5lite container. Returns the quantity names.
///
/// The archive opens lazily (`opts` carries the open-time knobs) and
/// all quantities decode concurrently on the session pool via
/// [`Engine::decompress_dataset`]: quantity *i+1*'s section I/O and
/// stage-2 inflate overlap quantity *i*'s block decode.
pub fn decompress_dataset_file(
    input: &Path,
    output: &Path,
    engine: &Engine,
    opts: &DatasetOptions,
) -> Result<Vec<String>> {
    let archive = opts.open(input).map_err(|e| anyhow!(e))?;
    let decoded = engine.decompress_dataset(&archive, None).map_err(|e| anyhow!(e))?;
    let mut datasets = Vec::with_capacity(decoded.len());
    for (name, field, _file) in &decoded {
        // name by the archive entry, not the inner .czb header: sections
        // repackaged under a new name must keep that name on the way out
        datasets.push(h5lite::Dataset::from_field(name, field));
    }
    h5lite::write(output, &datasets)?;
    Ok(datasets.into_iter().map(|d| d.name).collect())
}

/// One unit of a multi-file batch ([`compress_files`]): which dataset of
/// which h5lite container, compressed to which `.czb` path.
#[derive(Clone, Debug)]
pub struct CompressJob {
    pub input: PathBuf,
    pub dataset: String,
    pub output: PathBuf,
}

/// Run `batch.len()` tasks on up to `jobs` submitter threads pulling
/// from a shared cursor, collecting one result per task in batch order.
/// The engine's multi-generation pool is what lets the submissions
/// overlap; this helper only supplies the submitter threads.
fn run_batch<R: Send>(
    len: usize,
    jobs: usize,
    task: impl Fn(usize) -> Result<R> + Sync,
) -> Vec<Result<R>> {
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Mutex;
    let jobs = jobs.clamp(1, len.max(1));
    let slots: Vec<Mutex<Option<Result<R>>>> = (0..len).map(|_| Mutex::new(None)).collect();
    let cursor = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..jobs {
            s.spawn(|| loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= len {
                    break;
                }
                *slots[i].lock().unwrap() = Some(task(i));
            });
        }
    });
    slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("batch cursor covers every index"))
        .collect()
}

/// Ex-situ, multi-stream: compress a whole batch of (container, dataset)
/// pairs through ONE [`Engine`] session, `jobs` files in flight at a
/// time. Each submitter thread reads its container, submits the field
/// onto the shared pool (submissions overlap — idle workers steal across
/// the live streams) and streams the `.czb` to its output path. Every
/// output is byte-identical to compressing that file alone; a failing
/// job reports its own error without stopping the siblings. Returns
/// (dataset, stats) per job in batch order; the first failure, if any.
pub fn compress_files(
    batch: &[CompressJob],
    params: &CompressParams,
    engine: &Engine,
    jobs: usize,
) -> Result<Vec<(String, CompressStats)>> {
    use std::sync::OnceLock;
    // one parse per distinct container, loaded lazily by the first job
    // that touches it and shared by its siblings — h5lite::read pulls
    // the WHOLE file per call, so the common shape (every job a dataset
    // of one container) would otherwise read and hold `jobs` full
    // copies of the archive at once
    let distinct: Vec<&Path> = batch.iter().fold(Vec::new(), |mut acc, j| {
        if !acc.contains(&j.input.as_path()) {
            acc.push(j.input.as_path());
        }
        acc
    });
    let containers: Vec<OnceLock<Result<Vec<h5lite::Dataset>, String>>> =
        distinct.iter().map(|_| OnceLock::new()).collect();
    let results = run_batch(batch.len(), jobs, |i| {
        let job = &batch[i];
        let slot = distinct
            .iter()
            .position(|p| *p == job.input.as_path())
            .expect("every batch input is in the distinct list");
        let datasets = containers[slot]
            .get_or_init(|| h5lite::read_all(&job.input))
            .as_ref()
            .map_err(|e| anyhow!(e))?;
        let ds = datasets
            .iter()
            .find(|d| d.name == job.dataset)
            .ok_or_else(|| anyhow!("dataset {} not in {}", job.dataset, job.input.display()))?;
        let field = ds.to_field();
        let file = std::fs::File::create(&job.output)
            .with_context(|| format!("creating {}", job.output.display()))?;
        let mut sink = std::io::BufWriter::new(file);
        let stats = engine
            .compress(&field, &job.dataset, params, &mut sink)
            .with_context(|| format!("compressing {}", job.dataset))?;
        std::io::Write::flush(&mut sink)
            .with_context(|| format!("writing {}", job.output.display()))?;
        Ok(stats)
    });
    batch
        .iter()
        .zip(results)
        .map(|(job, r)| {
            r.map(|stats| (job.dataset.clone(), stats))
                .with_context(|| format!("job {}", job.output.display()))
        })
        .collect()
}

/// Ex-situ, multi-stream: decompress many `.czb` files through ONE
/// [`Engine`] session, `jobs` files in flight at a time (each becomes an
/// h5lite container at its paired output path). Bit-identical to
/// decompressing each file alone. Returns the dataset names in batch
/// order. Output paths must be pairwise distinct — jobs run
/// concurrently, so two pairs naming one output would race-write it
/// (the CLI refuses colliding file stems up front).
pub fn decompress_files(
    pairs: &[(PathBuf, PathBuf)],
    engine: &Engine,
    jobs: usize,
) -> Result<Vec<String>> {
    let results = run_batch(pairs.len(), jobs, |i| {
        let (input, output) = &pairs[i];
        let bytes =
            std::fs::read(input).with_context(|| format!("reading {}", input.display()))?;
        let (field, file) = engine.decompress_bytes(&bytes).map_err(|e| anyhow!(e))?;
        h5lite::write(output, &[h5lite::Dataset::from_field(&file.name, &field)])?;
        Ok(file.name)
    });
    pairs
        .iter()
        .zip(results)
        .map(|((input, _), r)| r.with_context(|| format!("job {}", input.display())))
        .collect()
}

/// Result of one in-situ dump step.
#[derive(Clone, Debug)]
pub struct DumpReport {
    pub stats: CompressStats,
    pub write: parallel::WriteReport,
    /// Total wall seconds for compress + write on this rank.
    pub total_secs: f64,
}

/// In-situ hook: each rank compresses its partition's field slab and all
/// ranks write one shared file per quantity via exscan offsets.
/// `field` here is this rank's local portion (equal-sized partitions).
pub fn dump_in_situ(
    field: &Field3,
    name: &str,
    path: &Path,
    cfg: &PipelineConfig,
    engine: &dyn WaveletEngine,
    comm: &dyn Comm,
) -> Result<DumpReport> {
    let t = std::time::Instant::now();
    let (bytes, stats) = compress_field(field, name, cfg, engine);
    // rank 0 writes a tiny global header: magic + rank count
    let mut header = Vec::new();
    header.extend_from_slice(b"CZBS");
    header.extend_from_slice(&(comm.size() as u32).to_le_bytes());
    let write = parallel::shared_write(
        path,
        comm,
        if comm.rank() == 0 { Some(&header) } else { None },
        8,
        &bytes,
    )?;
    Ok(DumpReport { stats, write, total_secs: t.elapsed().as_secs_f64() })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::SelfComm;
    use crate::pipeline::NativeEngine;
    use crate::sim::{step_to_time, CloudConfig, CloudSim, Qoi};

    fn tmp(name: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join("cubismz_coord_tests");
        std::fs::create_dir_all(&d).unwrap();
        d.join(name)
    }

    #[test]
    fn exsitu_compress_decompress_psnr_flow() {
        let sim = CloudSim::new(CloudConfig::paper(64));
        let f = sim.field(Qoi::Pressure, step_to_time(5000));
        let h5 = tmp("in.h5l");
        h5lite::write(&h5, &[h5lite::Dataset::from_field("p", &f)]).unwrap();
        let czb = tmp("p.czb");
        let cfg = PipelineConfig::paper_default(1e-3);
        let st = compress_file(&h5, "p", &czb, &cfg, &NativeEngine).unwrap();
        assert!(st.ratio() > 2.0);
        let p = psnr_file(&h5, "p", &czb, &NativeEngine).unwrap();
        assert!(p > 50.0, "psnr {p}");
        let out = tmp("p_out.h5l");
        let (name, field) = decompress_file(&czb, &out, &NativeEngine, 2).unwrap();
        assert_eq!(name, "p");
        assert_eq!(field.nx, 64);
        // the decompressed container reads back
        let ds = h5lite::read(&out, "p").unwrap();
        assert_eq!(ds.data.len(), 64 * 64 * 64);
    }

    #[test]
    fn recompress_changes_scheme() {
        let sim = CloudSim::new(CloudConfig::paper(32));
        let f = sim.field(Qoi::Density, step_to_time(5000));
        let h5 = tmp("rho.h5l");
        h5lite::write(&h5, &[h5lite::Dataset::from_field("rho", &f)]).unwrap();
        let czb = tmp("rho.czb");
        let cfg = PipelineConfig::paper_default(1e-4);
        compress_file(&h5, "rho", &czb, &cfg, &NativeEngine).unwrap();
        let czb2 = tmp("rho2.czb");
        let cfg2 = PipelineConfig::new(
            32,
            crate::pipeline::Stage1::Zfp { tol_rel: 1e-3 },
            crate::codec::Codec::None,
        );
        let st = recompress_file(&czb, &czb2, &cfg2, &NativeEngine).unwrap();
        assert!(st.ratio() > 1.0);
        let bytes = std::fs::read(&czb2).unwrap();
        let (file, _) = crate::pipeline::CzbFile::parse_header(&bytes).unwrap();
        assert!(matches!(file.stage1, crate::pipeline::Stage1::Zfp { .. }));
    }

    #[test]
    fn dataset_file_roundtrip_with_subset() {
        let sim = CloudSim::new(CloudConfig::paper(32));
        let h5 = tmp("step.h5l");
        let datasets: Vec<h5lite::Dataset> = Qoi::ALL
            .iter()
            .map(|q| h5lite::Dataset::from_field(q.name(), &sim.field(*q, step_to_time(5000))))
            .collect();
        h5lite::write(&h5, &datasets).unwrap();
        let czs = tmp("step.czs");
        let engine = Engine::builder().threads(2).build();
        let params = CompressParams::paper_default(1e-3);
        let stats =
            compress_dataset_file(&h5, Some("p,rho"), &czs, &params, &engine).unwrap();
        let names: Vec<&str> = stats.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names, vec!["p", "rho"]);
        let out = tmp("step_out.h5l");
        let back = decompress_dataset_file(&czs, &out, &engine, &DatasetOptions::new()).unwrap();
        assert_eq!(back, vec!["p".to_string(), "rho".to_string()]);
        let p = h5lite::read(&out, "p").unwrap();
        assert_eq!(p.data.len(), 32 * 32 * 32);
        // unknown subset errors instead of writing an empty archive —
        // and must not clobber the good archive already at the path
        assert!(compress_dataset_file(&h5, Some("nope"), &czs, &params, &engine).is_err());
        assert_eq!(Dataset::open(&czs).unwrap().names(), vec!["p", "rho"]);
    }

    #[test]
    fn failed_dataset_compression_leaves_no_partial_archive() {
        let sim = CloudSim::new(CloudConfig::paper(32));
        let h5 = tmp("atomic.h5l");
        h5lite::write(
            &h5,
            &[h5lite::Dataset::from_field("p", &sim.field(Qoi::Pressure, step_to_time(5000)))],
        )
        .unwrap();
        let czs = tmp("atomic.czs");
        let _ = std::fs::remove_file(&czs);
        // any leftover "atomic.czs.<pid>.<n>.tmp" sibling is a cleanup bug
        let stray_tmps = || {
            std::fs::read_dir(czs.parent().unwrap())
                .unwrap()
                .filter_map(|e| e.ok())
                .filter(|e| e.file_name().to_string_lossy().starts_with("atomic.czs."))
                .count()
        };
        let engine = Engine::builder().threads(2).build();
        let params = CompressParams::paper_default(1e-3);
        // "p" compresses fine, then the missing quantity fails the run
        // AFTER a section was already written — no partial .czs (and no
        // stray temp file) may remain at the output path
        assert!(compress_dataset_file(&h5, Some("p,ghost"), &czs, &params, &engine).is_err());
        assert!(!czs.exists(), "failed compression must not leave a partial archive");
        assert_eq!(stray_tmps(), 0, "temp file must be cleaned up on failure");
        // a successful run lands atomically and opens lazily
        compress_dataset_file(&h5, None, &czs, &params, &engine).unwrap();
        assert_eq!(stray_tmps(), 0, "temp file must be renamed away on success");
        let ds = Dataset::open(&czs).unwrap();
        assert!(ds.is_file_backed());
        assert_eq!(ds.names(), vec!["p"]);
        // a later failing run leaves the existing good archive untouched
        assert!(compress_dataset_file(&h5, Some("ghost"), &czs, &params, &engine).is_err());
        assert_eq!(Dataset::open(&czs).unwrap().names(), vec!["p"]);
    }

    #[test]
    fn multi_file_batch_through_one_engine() {
        let sim = CloudSim::new(CloudConfig::paper(32));
        let h5 = tmp("batch.h5l");
        let datasets: Vec<h5lite::Dataset> = Qoi::ALL
            .iter()
            .map(|q| h5lite::Dataset::from_field(q.name(), &sim.field(*q, step_to_time(5000))))
            .collect();
        h5lite::write(&h5, &datasets).unwrap();
        let engine = Engine::builder().threads(2).chunk_bytes(16 << 10).build();
        let params = CompressParams::paper_default(1e-3);
        let batch: Vec<CompressJob> = Qoi::ALL
            .iter()
            .map(|q| CompressJob {
                input: h5.clone(),
                dataset: q.name().to_string(),
                output: tmp(&format!("batch_{}.czb", q.name())),
            })
            .collect();
        let stats = compress_files(&batch, &params, &engine, batch.len()).unwrap();
        assert_eq!(stats.len(), Qoi::ALL.len());
        // every concurrently produced file is byte-identical to a lone
        // submission of the same quantity on the same session
        for job in &batch {
            let ds = h5lite::read(&h5, &job.dataset).unwrap();
            let (reference, _) = engine.compress_vec(&ds.to_field(), &job.dataset, &params);
            assert_eq!(std::fs::read(&job.output).unwrap(), reference, "{}", job.dataset);
        }
        // decompress the batch back through the same session
        let pairs: Vec<(PathBuf, PathBuf)> = batch
            .iter()
            .map(|j| (j.output.clone(), tmp(&format!("batch_{}_out.h5l", j.dataset))))
            .collect();
        let names = decompress_files(&pairs, &engine, 3).unwrap();
        let expected: Vec<String> = Qoi::ALL.iter().map(|q| q.name().to_string()).collect();
        assert_eq!(names, expected);
        for (j, (_, out)) in batch.iter().zip(&pairs) {
            let back = h5lite::read(out, &j.dataset).unwrap();
            assert_eq!(back.data.len(), 32 * 32 * 32, "{}", j.dataset);
        }
        // a bad job reports its own error; siblings still land on disk
        let mut bad = batch.clone();
        for j in &mut bad {
            let _ = std::fs::remove_file(&j.output);
        }
        bad[1].dataset = "ghost".to_string();
        let err = compress_files(&bad, &params, &engine, 2).unwrap_err().to_string();
        assert!(err.contains("job"), "{err}");
        assert!(bad[0].output.exists(), "healthy sibling must still be written");
        assert!(bad[2].output.exists(), "healthy sibling must still be written");
        // jobs=1 degenerates to the sequential flow with the same bytes
        let seq = compress_files(&batch, &params, &engine, 1).unwrap();
        assert_eq!(seq.len(), batch.len());
    }

    #[test]
    fn insitu_dump_single_rank() {
        let sim = CloudSim::new(CloudConfig::paper(64));
        let f = sim.field(Qoi::Alpha2, step_to_time(5000));
        let cfg = PipelineConfig::paper_default(1e-3);
        let path = tmp("a2_insitu.czbs");
        let rep = dump_in_situ(&f, "a2", &path, &cfg, &NativeEngine, &SelfComm).unwrap();
        assert!(rep.total_secs > 0.0);
        assert_eq!(rep.write.offset, 8);
        let file = std::fs::read(&path).unwrap();
        assert_eq!(&file[..4], b"CZBS");
        // payload after the global header is a valid czb stream
        let (field, czb) = decompress_field_mt(&file[8..], &NativeEngine, 2).unwrap();
        assert_eq!(czb.name, "a2");
        let p = psnr(&f.data, &field.data).unwrap();
        assert!(p > 40.0, "psnr {p}");
    }
}
