//! Coordinator (the L3 entry points): ex-situ tool operations over files
//! and the in-situ hook API a simulation embeds (paper §2: "When coupled
//! with simulation software ... CubismZ serves as a module for in situ
//! data compression").
use crate::anyhow;
use crate::cluster::Comm;
use crate::core::Field3;
use crate::io::{h5lite, parallel};
use crate::metrics::psnr;
use crate::pipeline::{
    compress_field, decompress_field_mt, CompressParams, CompressStats, Dataset, DatasetOptions,
    Engine, PipelineConfig, WaveletEngine,
};
use crate::util::error::{Context, Result};
use std::path::Path;

/// Ex-situ: read a dataset from an h5lite container, compress it, write
/// the `.czb` file. Returns the stats.
pub fn compress_file(
    input: &Path,
    dataset: &str,
    output: &Path,
    cfg: &PipelineConfig,
    engine: &dyn WaveletEngine,
) -> Result<CompressStats> {
    let ds = h5lite::read(input, dataset).map_err(|e| anyhow!(e))?;
    let field = ds.to_field();
    let (bytes, stats) = compress_field(&field, dataset, cfg, engine);
    std::fs::write(output, &bytes).with_context(|| format!("writing {}", output.display()))?;
    Ok(stats)
}

/// Ex-situ: decompress a `.czb` file back into an h5lite container
/// (paper: "they can be converted to HDF5 format and visualized").
/// Whole-field decompression runs chunk-parallel over `nthreads` workers
/// (paper §2.3 "parallel decompression").
pub fn decompress_file(
    input: &Path,
    output: &Path,
    engine: &dyn WaveletEngine,
    nthreads: usize,
) -> Result<(String, Field3)> {
    let bytes = std::fs::read(input).with_context(|| format!("reading {}", input.display()))?;
    let (field, file) = decompress_field_mt(&bytes, engine, nthreads).map_err(|e| anyhow!(e))?;
    h5lite::write(output, &[h5lite::Dataset::from_field(&file.name, &field)])?;
    Ok((file.name, field))
}

/// Recompress a `.czb` with a different configuration (paper: compressed
/// files can be "recompressed using any of the supported methods").
pub fn recompress_file(
    input: &Path,
    output: &Path,
    cfg: &PipelineConfig,
    engine: &dyn WaveletEngine,
) -> Result<CompressStats> {
    let bytes = std::fs::read(input)?;
    let (field, file) = decompress_field_mt(&bytes, engine, cfg.nthreads).map_err(|e| anyhow!(e))?;
    let (out, stats) = compress_field(&field, &file.name, cfg, engine);
    std::fs::write(output, &out)?;
    Ok(stats)
}

/// PSNR between a reference h5lite dataset and a compressed `.czb`.
pub fn psnr_file(
    reference: &Path,
    dataset: &str,
    compressed: &Path,
    engine: &dyn WaveletEngine,
) -> Result<f64> {
    let r = h5lite::read(reference, dataset).map_err(|e| anyhow!(e))?;
    let bytes = std::fs::read(compressed)?;
    let (d, _) = decompress_field_mt(&bytes, engine, 1).map_err(|e| anyhow!(e))?;
    if d.data.len() != r.data.len() {
        return Err(anyhow!("size mismatch: {} vs {}", d.data.len(), r.data.len()));
    }
    Ok(psnr(&r.data, &d.data))
}

/// Ex-situ: compress every dataset of an h5lite container (optionally a
/// comma-separated `only` subset) into one `.czs` archive on a single
/// [`Engine`] session — the multi-QoI shape of the paper's CFD workflow.
/// Returns (name, stats) per quantity in archive order.
///
/// The archive is built at a sibling temp path and renamed into place
/// only on success: a mid-archive failure must never leave a
/// trailer-less partial `.czs` at the output path, and a failing re-run
/// must not clobber an existing good archive.
pub fn compress_dataset_file(
    input: &Path,
    only: Option<&str>,
    output: &Path,
    params: &CompressParams,
    engine: &Engine,
) -> Result<Vec<(String, CompressStats)>> {
    // unique per process AND per call: two concurrent compressions to
    // the same output must not interleave writes into one temp file
    static TMP_COUNTER: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
    let mut tmp_name = output
        .file_name()
        .map(|n| n.to_os_string())
        .unwrap_or_else(|| std::ffi::OsString::from("archive.czs"));
    tmp_name.push(format!(
        ".{}.{}.tmp",
        std::process::id(),
        TMP_COUNTER.fetch_add(1, std::sync::atomic::Ordering::Relaxed)
    ));
    let tmp_path = output.with_file_name(tmp_name);
    match compress_dataset_to(input, only, &tmp_path, params, engine) {
        Ok(stats) => match std::fs::rename(&tmp_path, output) {
            Ok(()) => Ok(stats),
            Err(e) => {
                let _ = std::fs::remove_file(&tmp_path);
                Err(anyhow!("moving {} into place: {e}", output.display()))
            }
        },
        Err(e) => {
            let _ = std::fs::remove_file(&tmp_path);
            Err(e)
        }
    }
}

fn compress_dataset_to(
    input: &Path,
    only: Option<&str>,
    output: &Path,
    params: &CompressParams,
    engine: &Engine,
) -> Result<Vec<(String, CompressStats)>> {
    let wanted: Option<Vec<&str>> = only.map(|s| s.split(',').map(str::trim).collect());
    let names = h5lite::list(input).map_err(|e| anyhow!(e))?;
    let mut writer = Dataset::create(output)
        .with_context(|| format!("creating {}", output.display()))?;
    let mut out = Vec::new();
    for (name, ..) in names {
        if let Some(w) = &wanted {
            if !w.contains(&name.as_str()) {
                continue;
            }
        }
        let ds = h5lite::read(input, &name).map_err(|e| anyhow!(e))?;
        let field = ds.to_field();
        let stats = writer
            .write_quantity(engine, &field, &name, params)
            .with_context(|| format!("writing quantity {name}"))?;
        out.push((name, stats));
    }
    if let Some(w) = &wanted {
        // a typo'd subset name must fail loudly, not silently produce an
        // archive with a quantity missing
        let missing: Vec<&str> = w
            .iter()
            .filter(|n| !out.iter().any(|(name, _)| name == *n))
            .copied()
            .collect();
        if !missing.is_empty() {
            return Err(anyhow!(
                "requested quantities not in {}: {}",
                input.display(),
                missing.join(",")
            ));
        }
    }
    if out.is_empty() {
        return Err(anyhow!("no datasets matched in {}", input.display()));
    }
    writer.finish().with_context(|| format!("finishing {}", output.display()))?;
    Ok(out)
}

/// Ex-situ: decompress every quantity of a `.czs` archive back into one
/// h5lite container. Returns the quantity names.
///
/// The archive opens lazily (`opts` carries the open-time knobs) and
/// all quantities decode concurrently on the session pool via
/// [`Engine::decompress_dataset`]: quantity *i+1*'s section I/O and
/// stage-2 inflate overlap quantity *i*'s block decode.
pub fn decompress_dataset_file(
    input: &Path,
    output: &Path,
    engine: &Engine,
    opts: &DatasetOptions,
) -> Result<Vec<String>> {
    let archive = opts.open(input).map_err(|e| anyhow!(e))?;
    let decoded = engine.decompress_dataset(&archive, None).map_err(|e| anyhow!(e))?;
    let mut datasets = Vec::with_capacity(decoded.len());
    for (name, field, _file) in &decoded {
        // name by the archive entry, not the inner .czb header: sections
        // repackaged under a new name must keep that name on the way out
        datasets.push(h5lite::Dataset::from_field(name, field));
    }
    h5lite::write(output, &datasets)?;
    Ok(datasets.into_iter().map(|d| d.name).collect())
}

/// Result of one in-situ dump step.
#[derive(Clone, Debug)]
pub struct DumpReport {
    pub stats: CompressStats,
    pub write: parallel::WriteReport,
    /// Total wall seconds for compress + write on this rank.
    pub total_secs: f64,
}

/// In-situ hook: each rank compresses its partition's field slab and all
/// ranks write one shared file per quantity via exscan offsets.
/// `field` here is this rank's local portion (equal-sized partitions).
pub fn dump_in_situ(
    field: &Field3,
    name: &str,
    path: &Path,
    cfg: &PipelineConfig,
    engine: &dyn WaveletEngine,
    comm: &dyn Comm,
) -> Result<DumpReport> {
    let t = std::time::Instant::now();
    let (bytes, stats) = compress_field(field, name, cfg, engine);
    // rank 0 writes a tiny global header: magic + rank count
    let mut header = Vec::new();
    header.extend_from_slice(b"CZBS");
    header.extend_from_slice(&(comm.size() as u32).to_le_bytes());
    let write = parallel::shared_write(
        path,
        comm,
        if comm.rank() == 0 { Some(&header) } else { None },
        8,
        &bytes,
    )?;
    Ok(DumpReport { stats, write, total_secs: t.elapsed().as_secs_f64() })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::SelfComm;
    use crate::pipeline::NativeEngine;
    use crate::sim::{step_to_time, CloudConfig, CloudSim, Qoi};

    fn tmp(name: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join("cubismz_coord_tests");
        std::fs::create_dir_all(&d).unwrap();
        d.join(name)
    }

    #[test]
    fn exsitu_compress_decompress_psnr_flow() {
        let sim = CloudSim::new(CloudConfig::paper(64));
        let f = sim.field(Qoi::Pressure, step_to_time(5000));
        let h5 = tmp("in.h5l");
        h5lite::write(&h5, &[h5lite::Dataset::from_field("p", &f)]).unwrap();
        let czb = tmp("p.czb");
        let cfg = PipelineConfig::paper_default(1e-3);
        let st = compress_file(&h5, "p", &czb, &cfg, &NativeEngine).unwrap();
        assert!(st.ratio() > 2.0);
        let p = psnr_file(&h5, "p", &czb, &NativeEngine).unwrap();
        assert!(p > 50.0, "psnr {p}");
        let out = tmp("p_out.h5l");
        let (name, field) = decompress_file(&czb, &out, &NativeEngine, 2).unwrap();
        assert_eq!(name, "p");
        assert_eq!(field.nx, 64);
        // the decompressed container reads back
        let ds = h5lite::read(&out, "p").unwrap();
        assert_eq!(ds.data.len(), 64 * 64 * 64);
    }

    #[test]
    fn recompress_changes_scheme() {
        let sim = CloudSim::new(CloudConfig::paper(32));
        let f = sim.field(Qoi::Density, step_to_time(5000));
        let h5 = tmp("rho.h5l");
        h5lite::write(&h5, &[h5lite::Dataset::from_field("rho", &f)]).unwrap();
        let czb = tmp("rho.czb");
        let cfg = PipelineConfig::paper_default(1e-4);
        compress_file(&h5, "rho", &czb, &cfg, &NativeEngine).unwrap();
        let czb2 = tmp("rho2.czb");
        let cfg2 = PipelineConfig::new(
            32,
            crate::pipeline::Stage1::Zfp { tol_rel: 1e-3 },
            crate::codec::Codec::None,
        );
        let st = recompress_file(&czb, &czb2, &cfg2, &NativeEngine).unwrap();
        assert!(st.ratio() > 1.0);
        let bytes = std::fs::read(&czb2).unwrap();
        let (file, _) = crate::pipeline::CzbFile::parse_header(&bytes).unwrap();
        assert!(matches!(file.stage1, crate::pipeline::Stage1::Zfp { .. }));
    }

    #[test]
    fn dataset_file_roundtrip_with_subset() {
        let sim = CloudSim::new(CloudConfig::paper(32));
        let h5 = tmp("step.h5l");
        let datasets: Vec<h5lite::Dataset> = Qoi::ALL
            .iter()
            .map(|q| h5lite::Dataset::from_field(q.name(), &sim.field(*q, step_to_time(5000))))
            .collect();
        h5lite::write(&h5, &datasets).unwrap();
        let czs = tmp("step.czs");
        let engine = Engine::builder().threads(2).build();
        let params = CompressParams::paper_default(1e-3);
        let stats =
            compress_dataset_file(&h5, Some("p,rho"), &czs, &params, &engine).unwrap();
        let names: Vec<&str> = stats.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names, vec!["p", "rho"]);
        let out = tmp("step_out.h5l");
        let back = decompress_dataset_file(&czs, &out, &engine, &DatasetOptions::new()).unwrap();
        assert_eq!(back, vec!["p".to_string(), "rho".to_string()]);
        let p = h5lite::read(&out, "p").unwrap();
        assert_eq!(p.data.len(), 32 * 32 * 32);
        // unknown subset errors instead of writing an empty archive —
        // and must not clobber the good archive already at the path
        assert!(compress_dataset_file(&h5, Some("nope"), &czs, &params, &engine).is_err());
        assert_eq!(Dataset::open(&czs).unwrap().names(), vec!["p", "rho"]);
    }

    #[test]
    fn failed_dataset_compression_leaves_no_partial_archive() {
        let sim = CloudSim::new(CloudConfig::paper(32));
        let h5 = tmp("atomic.h5l");
        h5lite::write(
            &h5,
            &[h5lite::Dataset::from_field("p", &sim.field(Qoi::Pressure, step_to_time(5000)))],
        )
        .unwrap();
        let czs = tmp("atomic.czs");
        let _ = std::fs::remove_file(&czs);
        // any leftover "atomic.czs.<pid>.<n>.tmp" sibling is a cleanup bug
        let stray_tmps = || {
            std::fs::read_dir(czs.parent().unwrap())
                .unwrap()
                .filter_map(|e| e.ok())
                .filter(|e| e.file_name().to_string_lossy().starts_with("atomic.czs."))
                .count()
        };
        let engine = Engine::builder().threads(2).build();
        let params = CompressParams::paper_default(1e-3);
        // "p" compresses fine, then the missing quantity fails the run
        // AFTER a section was already written — no partial .czs (and no
        // stray temp file) may remain at the output path
        assert!(compress_dataset_file(&h5, Some("p,ghost"), &czs, &params, &engine).is_err());
        assert!(!czs.exists(), "failed compression must not leave a partial archive");
        assert_eq!(stray_tmps(), 0, "temp file must be cleaned up on failure");
        // a successful run lands atomically and opens lazily
        compress_dataset_file(&h5, None, &czs, &params, &engine).unwrap();
        assert_eq!(stray_tmps(), 0, "temp file must be renamed away on success");
        let ds = Dataset::open(&czs).unwrap();
        assert!(ds.is_file_backed());
        assert_eq!(ds.names(), vec!["p"]);
        // a later failing run leaves the existing good archive untouched
        assert!(compress_dataset_file(&h5, Some("ghost"), &czs, &params, &engine).is_err());
        assert_eq!(Dataset::open(&czs).unwrap().names(), vec!["p"]);
    }

    #[test]
    fn insitu_dump_single_rank() {
        let sim = CloudSim::new(CloudConfig::paper(64));
        let f = sim.field(Qoi::Alpha2, step_to_time(5000));
        let cfg = PipelineConfig::paper_default(1e-3);
        let path = tmp("a2_insitu.czbs");
        let rep = dump_in_situ(&f, "a2", &path, &cfg, &NativeEngine, &SelfComm).unwrap();
        assert!(rep.total_secs > 0.0);
        assert_eq!(rep.write.offset, 8);
        let file = std::fs::read(&path).unwrap();
        assert_eq!(&file[..4], b"CZBS");
        // payload after the global header is a valid czb stream
        let (field, czb) = decompress_field_mt(&file[8..], &NativeEngine, 2).unwrap();
        assert_eq!(czb.name, "a2");
        let p = psnr(&f.data, &field.data);
        assert!(p > 40.0, "psnr {p}");
    }
}
