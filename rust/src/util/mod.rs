//! Small self-contained utilities: bit-level I/O, a seeded PRNG (the image
//! has no `rand`), a property-test helper, a micro-benchmark harness
//! (the image has no `criterion`), a slice-by-8 CRC32C (the image has no
//! `crc32fast`), and a minimal error type (the image has no `anyhow`).
pub mod bench;
pub mod bitio;
pub mod crc32c;
pub mod error;
pub mod prng;
pub mod prop;
pub mod timer;

pub use bitio::{BitReader, BitWriter};
pub use prng::Pcg32;
pub use timer::Timer;
