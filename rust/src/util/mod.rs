//! Small self-contained utilities: bit-level I/O, a seeded PRNG (the image
//! has no `rand`), a property-test helper, and a micro-benchmark harness
//! (the image has no `criterion`).
pub mod bench;
pub mod bitio;
pub mod prng;
pub mod prop;
pub mod timer;

pub use bitio::{BitReader, BitWriter};
pub use prng::Pcg32;
pub use timer::Timer;
