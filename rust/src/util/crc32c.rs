//! CRC32C (Castagnoli) — the integrity checksum behind `.czb` v4 and
//! `.czs` v2 ([`crate::pipeline::format`]). Implemented in-tree
//! (the offline image has no `crc32fast`/`crc32c` crate) with the
//! classic slice-by-8 table method: eight 256-entry tables, built once
//! in a `const fn`, let the hot loop fold 8 input bytes per iteration
//! instead of 1. Reflected polynomial `0x82F63B78`, init/xorout
//! `0xFFFFFFFF` — the same parameterization iSCSI, ext4 and the SSE4.2
//! `crc32` instruction use, so the known-answer vector
//! `crc32c(b"123456789") == 0xE3069283` pins the implementation.

const POLY: u32 = 0x82F6_3B78;

const fn build_tables() -> [[u32; 256]; 8] {
    let mut t = [[0u32; 256]; 8];
    let mut i = 0;
    while i < 256 {
        let mut crc = i as u32;
        let mut k = 0;
        while k < 8 {
            crc = if crc & 1 != 0 { (crc >> 1) ^ POLY } else { crc >> 1 };
            k += 1;
        }
        t[0][i] = crc;
        i += 1;
    }
    let mut j = 1;
    while j < 8 {
        let mut i = 0;
        while i < 256 {
            t[j][i] = (t[j - 1][i] >> 8) ^ t[0][(t[j - 1][i] & 0xFF) as usize];
            i += 1;
        }
        j += 1;
    }
    t
}

static TABLES: [[u32; 256]; 8] = build_tables();

/// CRC32C of `data` in one shot.
pub fn crc32c(data: &[u8]) -> u32 {
    crc32c_append(0, data)
}

/// Extend a previous [`crc32c`] result with more bytes:
/// `crc32c_append(crc32c(a), b) == crc32c(a ++ b)`.
pub fn crc32c_append(crc: u32, data: &[u8]) -> u32 {
    let mut crc = !crc;
    let mut chunks = data.chunks_exact(8);
    for b in &mut chunks {
        let lo = crc ^ u32::from_le_bytes([b[0], b[1], b[2], b[3]]);
        let hi = u32::from_le_bytes([b[4], b[5], b[6], b[7]]);
        crc = TABLES[7][(lo & 0xFF) as usize]
            ^ TABLES[6][((lo >> 8) & 0xFF) as usize]
            ^ TABLES[5][((lo >> 16) & 0xFF) as usize]
            ^ TABLES[4][(lo >> 24) as usize]
            ^ TABLES[3][(hi & 0xFF) as usize]
            ^ TABLES[2][((hi >> 8) & 0xFF) as usize]
            ^ TABLES[1][((hi >> 16) & 0xFF) as usize]
            ^ TABLES[0][(hi >> 24) as usize];
    }
    for &b in chunks.remainder() {
        crc = (crc >> 8) ^ TABLES[0][((crc ^ b as u32) & 0xFF) as usize];
    }
    !crc
}

/// Streaming CRC32C for writers that see the bytes in pieces (the
/// `.czs` [`crate::pipeline::dataset::DatasetWriter`] accumulates each
/// section's digest as the engine streams it out).
#[derive(Clone, Copy, Debug, Default)]
pub struct Crc32c(u32);

impl Crc32c {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn update(&mut self, data: &[u8]) {
        self.0 = crc32c_append(self.0, data);
    }

    pub fn finish(&self) -> u32 {
        self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg32;

    #[test]
    fn known_answer_vector() {
        // the canonical iSCSI/RFC 3720 check value
        assert_eq!(crc32c(b"123456789"), 0xE306_9283);
        assert_eq!(crc32c(b""), 0);
        // single byte exercises only the tail loop
        assert_eq!(crc32c(b"a"), crc32c_append(0, b"a"));
    }

    #[test]
    fn append_matches_one_shot_at_every_split() {
        let mut rng = Pcg32::new(0x51AB);
        let data: Vec<u8> = (0..257).map(|_| rng.next_u32() as u8).collect();
        let whole = crc32c(&data);
        for split in 0..data.len() {
            let (a, b) = data.split_at(split);
            assert_eq!(crc32c_append(crc32c(a), b), whole, "split {split}");
        }
    }

    #[test]
    fn streaming_struct_matches_one_shot() {
        let mut rng = Pcg32::new(7);
        let data: Vec<u8> = (0..4096).map(|_| rng.next_u32() as u8).collect();
        let mut h = Crc32c::new();
        for piece in data.chunks(13) {
            h.update(piece);
        }
        assert_eq!(h.finish(), crc32c(&data));
    }

    #[test]
    fn detects_single_bit_flips() {
        let mut rng = Pcg32::new(99);
        let mut data: Vec<u8> = (0..100).map(|_| rng.next_u32() as u8).collect();
        let clean = crc32c(&data);
        for i in 0..data.len() {
            for bit in 0..8 {
                data[i] ^= 1 << bit;
                assert_ne!(crc32c(&data), clean, "flip byte {i} bit {bit}");
                data[i] ^= 1 << bit;
            }
        }
    }
}
