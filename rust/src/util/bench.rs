//! Minimal criterion-replacement: warmup + sampled measurement with
//! mean / stddev / min, plus MB/s throughput reporting and a tiny JSON
//! value writer for the machine-readable `BENCH_*.json` artifacts the
//! perf-tracking benches emit. Used by the `rust/benches/*` harness=false
//! bench binaries.
use std::time::Instant;

/// Result of a micro-benchmark run.
#[derive(Clone, Debug)]
pub struct BenchStats {
    pub name: String,
    pub samples: Vec<f64>,
    pub mean: f64,
    pub stddev: f64,
    pub min: f64,
    pub median: f64,
}

impl BenchStats {
    /// Throughput in MB/s given bytes processed per iteration.
    pub fn mbps(&self, bytes: usize) -> f64 {
        bytes as f64 / 1e6 / self.mean
    }

    pub fn report(&self) {
        println!(
            "{:40} mean {:>10.4} ms  ±{:>8.4}  min {:>10.4} ms  (n={})",
            self.name,
            self.mean * 1e3,
            self.stddev * 1e3,
            self.min * 1e3,
            self.samples.len()
        );
    }

    pub fn report_mbps(&self, bytes: usize) {
        println!(
            "{:40} mean {:>10.4} ms  min {:>10.4} ms  {:>9.1} MB/s",
            self.name,
            self.mean * 1e3,
            self.min * 1e3,
            self.mbps(bytes)
        );
    }
}

/// Benchmark `f` with `warmup` unmeasured and `samples` measured runs.
pub fn bench<T>(name: &str, warmup: usize, samples: usize, mut f: impl FnMut() -> T) -> BenchStats {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut times = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t = Instant::now();
        std::hint::black_box(f());
        times.push(t.elapsed().as_secs_f64());
    }
    stats(name, times)
}

/// Benchmark with a per-sample time budget: runs at least 3 and at most
/// `max_samples` iterations, stopping once `budget_secs` is exceeded.
pub fn bench_budget<T>(
    name: &str,
    budget_secs: f64,
    max_samples: usize,
    mut f: impl FnMut() -> T,
) -> BenchStats {
    std::hint::black_box(f()); // warmup
    let start = Instant::now();
    let mut times = Vec::new();
    while times.len() < 3
        || (start.elapsed().as_secs_f64() < budget_secs && times.len() < max_samples)
    {
        let t = Instant::now();
        std::hint::black_box(f());
        times.push(t.elapsed().as_secs_f64());
    }
    stats(name, times)
}

fn stats(name: &str, mut times: Vec<f64>) -> BenchStats {
    let n = times.len() as f64;
    let mean = times.iter().sum::<f64>() / n;
    let var = times.iter().map(|t| (t - mean) * (t - mean)).sum::<f64>() / n;
    let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = times[times.len() / 2];
    BenchStats { name: name.to_string(), samples: times, mean, stddev: var.sqrt(), min, median }
}

/// Minimal JSON value for `BENCH_*.json` perf artifacts (the image has no
/// serde; this covers exactly what the benches emit).
pub enum Json {
    Num(f64),
    Int(i64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn render(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Num(v) => {
                if v.is_finite() {
                    out.push_str(&format!("{v}"));
                } else {
                    out.push_str("null");
                }
            }
            Json::Int(v) => out.push_str(&v.to_string()),
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Write a JSON value to `path` (with trailing newline).
pub fn write_json(path: impl AsRef<std::path::Path>, v: &Json) -> std::io::Result<()> {
    std::fs::write(path, v.render() + "\n")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let s = bench("noop", 1, 5, || 1 + 1);
        assert_eq!(s.samples.len(), 5);
        assert!(s.mean >= 0.0);
        assert!(s.min <= s.mean + 1e-12);
    }

    #[test]
    fn budget_stops() {
        let s = bench_budget("sleepy", 0.02, 1000, || {
            std::thread::sleep(std::time::Duration::from_millis(1))
        });
        assert!(s.samples.len() >= 3);
        assert!(s.samples.len() < 1000);
    }

    #[test]
    fn mbps_positive() {
        let s = bench("noop", 0, 3, || ());
        assert!(s.mbps(1_000_000) > 0.0);
    }

    #[test]
    fn json_renders_valid_structures() {
        let v = Json::Obj(vec![
            ("name".into(), Json::Str("a\"b\\c\n".into())),
            ("n".into(), Json::Int(-3)),
            ("x".into(), Json::Num(1.5)),
            ("bad".into(), Json::Num(f64::NAN)),
            ("arr".into(), Json::Arr(vec![Json::Int(1), Json::Int(2)])),
        ]);
        assert_eq!(
            v.render(),
            "{\"name\":\"a\\\"b\\\\c\\u000a\",\"n\":-3,\"x\":1.5,\"bad\":null,\"arr\":[1,2]}"
        );
    }

    #[test]
    fn json_file_roundtrips_through_python_style_parse() {
        let d = std::env::temp_dir().join("cubismz_bench_tests");
        std::fs::create_dir_all(&d).unwrap();
        let p = d.join("bench.json");
        write_json(&p, &Json::Arr(vec![Json::Num(2.0)])).unwrap();
        assert_eq!(std::fs::read_to_string(&p).unwrap(), "[2]\n");
    }
}
