//! Minimal criterion-replacement: warmup + sampled measurement with
//! mean / stddev / min, plus MB/s throughput reporting. Used by the
//! `rust/benches/*` harness=false bench binaries.
use std::time::Instant;

/// Result of a micro-benchmark run.
#[derive(Clone, Debug)]
pub struct BenchStats {
    pub name: String,
    pub samples: Vec<f64>,
    pub mean: f64,
    pub stddev: f64,
    pub min: f64,
    pub median: f64,
}

impl BenchStats {
    /// Throughput in MB/s given bytes processed per iteration.
    pub fn mbps(&self, bytes: usize) -> f64 {
        bytes as f64 / 1e6 / self.mean
    }

    pub fn report(&self) {
        println!(
            "{:40} mean {:>10.4} ms  ±{:>8.4}  min {:>10.4} ms  (n={})",
            self.name,
            self.mean * 1e3,
            self.stddev * 1e3,
            self.min * 1e3,
            self.samples.len()
        );
    }

    pub fn report_mbps(&self, bytes: usize) {
        println!(
            "{:40} mean {:>10.4} ms  min {:>10.4} ms  {:>9.1} MB/s",
            self.name,
            self.mean * 1e3,
            self.min * 1e3,
            self.mbps(bytes)
        );
    }
}

/// Benchmark `f` with `warmup` unmeasured and `samples` measured runs.
pub fn bench<T>(name: &str, warmup: usize, samples: usize, mut f: impl FnMut() -> T) -> BenchStats {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut times = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t = Instant::now();
        std::hint::black_box(f());
        times.push(t.elapsed().as_secs_f64());
    }
    stats(name, times)
}

/// Benchmark with a per-sample time budget: runs at least 3 and at most
/// `max_samples` iterations, stopping once `budget_secs` is exceeded.
pub fn bench_budget<T>(
    name: &str,
    budget_secs: f64,
    max_samples: usize,
    mut f: impl FnMut() -> T,
) -> BenchStats {
    std::hint::black_box(f()); // warmup
    let start = Instant::now();
    let mut times = Vec::new();
    while times.len() < 3
        || (start.elapsed().as_secs_f64() < budget_secs && times.len() < max_samples)
    {
        let t = Instant::now();
        std::hint::black_box(f());
        times.push(t.elapsed().as_secs_f64());
    }
    stats(name, times)
}

fn stats(name: &str, mut times: Vec<f64>) -> BenchStats {
    let n = times.len() as f64;
    let mean = times.iter().sum::<f64>() / n;
    let var = times.iter().map(|t| (t - mean) * (t - mean)).sum::<f64>() / n;
    let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = times[times.len() / 2];
    BenchStats { name: name.to_string(), samples: times, mean, stddev: var.sqrt(), min, median }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_collects_samples() {
        let s = bench("noop", 1, 5, || 1 + 1);
        assert_eq!(s.samples.len(), 5);
        assert!(s.mean >= 0.0);
        assert!(s.min <= s.mean + 1e-12);
    }

    #[test]
    fn budget_stops() {
        let s = bench_budget("sleepy", 0.02, 1000, || {
            std::thread::sleep(std::time::Duration::from_millis(1))
        });
        assert!(s.samples.len() >= 3);
        assert!(s.samples.len() < 1000);
    }

    #[test]
    fn mbps_positive() {
        let s = bench("noop", 0, 3, || ());
        assert!(s.mbps(1_000_000) > 0.0);
    }
}
