//! Tiny property-testing helper (the image has no `proptest`): runs a
//! predicate over `cases` seeded-random inputs and reports the failing seed.
use super::prng::Pcg32;

/// Run `f(rng, case_index)` for `cases` cases; panic with the seed on failure.
pub fn prop_cases(seed: u64, cases: usize, mut f: impl FnMut(&mut Pcg32, usize)) {
    for i in 0..cases {
        let case_seed = seed ^ (i as u64).wrapping_mul(0x9e37_79b9_7f4a_7c15);
        let mut rng = Pcg32::new(case_seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            f(&mut rng, i);
        }));
        if let Err(e) = result {
            eprintln!("property failed at case {i} (seed {case_seed:#x})");
            std::panic::resume_unwind(e);
        }
    }
}

/// Generate a random f32 vector with a mix of scales and special values —
/// the adversarial input profile for float compressors.
pub fn gen_floats(rng: &mut Pcg32, n: usize) -> Vec<f32> {
    (0..n)
        .map(|_| match rng.below(20) {
            0 => 0.0,
            1 => -0.0,
            2 => f32::MIN_POSITIVE,
            3 => 1e30,
            4 => -1e30,
            5 => 1e-30,
            _ => {
                let mag = 10f32.powi(rng.below(13) as i32 - 6);
                (rng.next_f32() * 2.0 - 1.0) * mag
            }
        })
        .collect()
}

/// Generate a smooth (spatially coherent) 3D field of side `n` — the
/// friendly input profile (what simulation data looks like).
pub fn gen_smooth_field(rng: &mut Pcg32, n: usize) -> Vec<f32> {
    let kx = rng.range_f64(0.5, 1.75);
    let ky = rng.range_f64(0.5, 1.75);
    let kz = rng.range_f64(0.5, 1.75);
    let phase = rng.range_f64(0.0, 6.28);
    let amp = rng.range_f64(0.1, 100.0);
    let mut out = Vec::with_capacity(n * n * n);
    for z in 0..n {
        for y in 0..n {
            for x in 0..n {
                let (fx, fy, fz) =
                    (x as f64 / n as f64, y as f64 / n as f64, z as f64 / n as f64);
                let v = (kx * fx * 6.28 + phase).sin()
                    * (ky * fy * 6.28).cos()
                    * (kz * fz * 6.28 + 0.5 * phase).sin();
                out.push((amp * v) as f32);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prop_runs_all_cases() {
        let mut count = 0;
        prop_cases(1, 25, |_, _| count += 1);
        assert_eq!(count, 25);
    }

    #[test]
    #[should_panic]
    fn prop_reports_failure() {
        prop_cases(1, 10, |_, i| assert!(i < 5));
    }

    #[test]
    fn gen_floats_has_specials() {
        let mut rng = Pcg32::new(5);
        let v = gen_floats(&mut rng, 4096);
        assert!(v.iter().any(|x| *x == 0.0));
        assert!(v.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn smooth_field_sized() {
        let mut rng = Pcg32::new(6);
        let v = gen_smooth_field(&mut rng, 8);
        assert_eq!(v.len(), 512);
    }
}
