//! Seeded PRNG (PCG32) — deterministic across platforms; used by the
//! synthetic simulator, property tests and workload generators.

/// PCG-XSH-RR 64/32 (O'Neill 2014). Small, fast, reproducible.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    pub fn new(seed: u64) -> Self {
        Self::with_stream(seed, 0xda3e_39cb_94b9_5bdb)
    }

    pub fn with_stream(seed: u64, stream: u64) -> Self {
        let mut s = Self { state: 0, inc: (stream << 1) | 1 };
        s.next_u32();
        s.state = s.state.wrapping_add(seed);
        s.next_u32();
        s
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6364136223846793005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: u32) -> u32 {
        ((self.next_u32() as u64 * n as u64) >> 32) as u32
    }

    /// Standard normal via Box–Muller.
    pub fn next_gaussian(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            let u2 = self.next_f64();
            if u1 > 1e-300 {
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Lognormal with parameters of the underlying normal.
    pub fn next_lognormal(&mut self, mu: f64, sigma: f64) -> f64 {
        (mu + sigma * self.next_gaussian()).exp()
    }

    /// Fill a slice with uniform floats in [lo, hi).
    pub fn fill_f32(&mut self, out: &mut [f32], lo: f32, hi: f32) {
        for v in out {
            *v = lo + (hi - lo) * self.next_f32();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Pcg32::new(7);
        let mut b = Pcg32::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg32::new(1);
        let mut b = Pcg32::new(2);
        let same = (0..32).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_mean() {
        let mut r = Pcg32::new(3);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.next_f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Pcg32::new(9);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.next_gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn below_is_in_range() {
        let mut r = Pcg32::new(11);
        for _ in 0..10_000 {
            assert!(r.below(17) < 17);
        }
    }
}
