//! LSB-first bit-level reader/writer used by the entropy coders
//! (czlib Huffman, zfp bit planes, fpzip residual codes).

/// LSB-first bit writer over a growable byte vector.
#[derive(Default)]
pub struct BitWriter {
    buf: Vec<u8>,
    acc: u64,
    nbits: u32,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_capacity(cap: usize) -> Self {
        Self { buf: Vec::with_capacity(cap), acc: 0, nbits: 0 }
    }

    /// Write the `n` low bits of `v` (LSB first). `n <= 57` per call.
    #[inline]
    pub fn write_bits(&mut self, v: u64, n: u32) {
        debug_assert!(n <= 57, "write_bits supports at most 57 bits per call");
        debug_assert!(n == 64 || v < (1u64 << n));
        self.acc |= v << self.nbits;
        self.nbits += n;
        while self.nbits >= 8 {
            self.buf.push((self.acc & 0xff) as u8);
            self.acc >>= 8;
            self.nbits -= 8;
        }
    }

    /// Write a single bit.
    #[inline]
    pub fn write_bit(&mut self, b: bool) {
        self.write_bits(b as u64, 1);
    }

    /// Number of whole bits written so far.
    pub fn bit_len(&self) -> usize {
        self.buf.len() * 8 + self.nbits as usize
    }

    /// Flush partial byte (zero-padded) and return the buffer.
    pub fn finish(mut self) -> Vec<u8> {
        if self.nbits > 0 {
            self.buf.push((self.acc & 0xff) as u8);
        }
        self.buf
    }

    /// Align to the next byte boundary with zero bits.
    pub fn align_byte(&mut self) {
        if self.nbits > 0 {
            self.buf.push((self.acc & 0xff) as u8);
            self.acc = 0;
            self.nbits = 0;
        }
    }
}

/// LSB-first bit reader over a byte slice.
pub struct BitReader<'a> {
    buf: &'a [u8],
    pos: usize,
    acc: u64,
    nbits: u32,
}

impl<'a> BitReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0, acc: 0, nbits: 0 }
    }

    #[inline]
    fn refill(&mut self) {
        while self.nbits <= 56 && self.pos < self.buf.len() {
            self.acc |= (self.buf[self.pos] as u64) << self.nbits;
            self.pos += 1;
            self.nbits += 8;
        }
    }

    /// Read `n <= 57` bits, LSB first. Reading past the end yields zeros
    /// (callers track logical length themselves).
    #[inline]
    pub fn read_bits(&mut self, n: u32) -> u64 {
        debug_assert!(n <= 57);
        if n == 0 {
            return 0;
        }
        if self.nbits < n {
            self.refill();
        }
        let v = self.acc & ((1u64 << n) - 1);
        self.acc >>= n;
        self.nbits = self.nbits.saturating_sub(n);
        v
    }

    #[inline]
    pub fn read_bit(&mut self) -> bool {
        self.read_bits(1) != 0
    }

    /// Peek up to 16 bits without consuming (for table-driven Huffman).
    #[inline]
    pub fn peek16(&mut self) -> u16 {
        if self.nbits < 16 {
            self.refill();
        }
        (self.acc & 0xffff) as u16
    }

    /// Consume `n` bits previously peeked.
    #[inline]
    pub fn consume(&mut self, n: u32) {
        self.acc >>= n;
        self.nbits = self.nbits.saturating_sub(n);
    }

    /// Discard bits up to the next byte boundary.
    pub fn align_byte(&mut self) {
        let r = self.nbits % 8;
        if r != 0 {
            self.consume(r);
        }
    }

    /// Number of bytes fully or partially consumed.
    pub fn bytes_consumed(&self) -> usize {
        self.pos - (self.nbits as usize) / 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Pcg32;

    #[test]
    fn roundtrip_fixed_widths() {
        let mut w = BitWriter::new();
        for i in 0..1000u64 {
            w.write_bits(i & 0x7f, 7);
        }
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        for i in 0..1000u64 {
            assert_eq!(r.read_bits(7), i & 0x7f);
        }
    }

    #[test]
    fn roundtrip_random_widths() {
        let mut rng = Pcg32::new(42);
        let items: Vec<(u64, u32)> = (0..5000)
            .map(|_| {
                let n = 1 + (rng.next_u32() % 57);
                let v = rng.next_u64() & ((1u64 << n) - 1);
                (v, n)
            })
            .collect();
        let mut w = BitWriter::new();
        for &(v, n) in &items {
            w.write_bits(v, n);
        }
        let bytes = w.finish();
        let mut r = BitReader::new(&bytes);
        for &(v, n) in &items {
            assert_eq!(r.read_bits(n), v, "width {n}");
        }
    }

    #[test]
    fn single_bits_and_alignment() {
        let mut w = BitWriter::new();
        w.write_bit(true);
        w.write_bit(false);
        w.write_bit(true);
        w.align_byte();
        w.write_bits(0xAB, 8);
        let bytes = w.finish();
        assert_eq!(bytes.len(), 2);
        let mut r = BitReader::new(&bytes);
        assert!(r.read_bit());
        assert!(!r.read_bit());
        assert!(r.read_bit());
        r.align_byte();
        assert_eq!(r.read_bits(8), 0xAB);
    }

    #[test]
    fn bit_len_tracks_written_bits() {
        let mut w = BitWriter::new();
        assert_eq!(w.bit_len(), 0);
        w.write_bits(1, 3);
        assert_eq!(w.bit_len(), 3);
        w.write_bits(0x1f, 13);
        assert_eq!(w.bit_len(), 16);
    }

    #[test]
    fn read_past_end_yields_zeros() {
        let bytes = vec![0xffu8];
        let mut r = BitReader::new(&bytes);
        assert_eq!(r.read_bits(8), 0xff);
        assert_eq!(r.read_bits(16), 0);
    }
}
