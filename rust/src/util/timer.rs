//! Wall-clock timing helpers.
use std::time::Instant;

/// Simple wall-clock timer.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    /// Elapsed seconds.
    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    /// Elapsed milliseconds.
    pub fn millis(&self) -> f64 {
        self.secs() * 1e3
    }

    /// Restart and return elapsed seconds since last start.
    pub fn lap(&mut self) -> f64 {
        let s = self.secs();
        self.start = Instant::now();
        s
    }
}

/// Time a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t = Timer::start();
    let r = f();
    (r, t.secs())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_monotonic() {
        let t = Timer::start();
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(t.secs() >= 0.004);
    }

    #[test]
    fn timed_returns_value() {
        let (v, s) = timed(|| 42);
        assert_eq!(v, 42);
        assert!(s >= 0.0);
    }
}
