//! Minimal error plumbing for the binaries, the coordinator and the
//! runtime: the offline image has no `anyhow`, so this provides the small
//! subset the codebase uses — a string-backed [`Error`], the [`anyhow!`]
//! constructor macro and the [`Context`] extension trait. Context chains
//! are folded into the message at construction time ("ctx: cause"), which
//! is all the CLI error reporting needs.
//!
//! [`anyhow!`]: crate::anyhow
use std::fmt;

/// String-backed error; context is folded into the message eagerly.
pub struct Error(String);

impl Error {
    pub fn msg(m: impl Into<String>) -> Self {
        Error(m.into())
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

// Debug prints the message itself so `.expect()` / `{:?}` stay readable.
impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error(e.to_string())
    }
}

impl From<String> for Error {
    fn from(e: String) -> Self {
        Error(e)
    }
}

impl From<&str> for Error {
    fn from(e: &str) -> Self {
        Error(e.to_string())
    }
}

/// `Result` defaulting to [`Error`] (drop-in for `anyhow::Result`).
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach a human-readable prefix to any displayable error.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display>(self, f: impl FnOnce() -> C) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| Error(format!("{c}: {e}")))
    }

    fn with_context<C: fmt::Display>(self, f: impl FnOnce() -> C) -> Result<T> {
        self.map_err(|e| Error(format!("{}: {e}", f())))
    }
}

/// Drop-in for `anyhow::anyhow!`: a format string (with inline captures)
/// or any single `Display` expression.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::util::error::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::util::error::Error::msg(format!("{}", $err))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($fmt, $($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::anyhow;

    #[test]
    fn macro_accepts_literals_args_and_exprs() {
        let name = "x";
        assert_eq!(anyhow!("missing --{name}").to_string(), "missing --x");
        assert_eq!(anyhow!("a {} b {}", 1, 2).to_string(), "a 1 b 2");
        let cause: String = "boom".into();
        assert_eq!(anyhow!(cause).to_string(), "boom");
    }

    #[test]
    fn context_prefixes_cause() {
        let r: std::result::Result<(), String> = Err("cause".into());
        let e = r.context("ctx").unwrap_err();
        assert_eq!(e.to_string(), "ctx: cause");
        let r: std::result::Result<(), String> = Err("cause".into());
        let e = r.with_context(|| format!("f{}", 1)).unwrap_err();
        assert_eq!(e.to_string(), "f1: cause");
    }

    #[test]
    fn io_and_string_convert() {
        fn f() -> Result<()> {
            Err(std::io::Error::new(std::io::ErrorKind::Other, "io"))?;
            Ok(())
        }
        assert!(f().unwrap_err().to_string().contains("io"));
        let e: Error = "s".into();
        assert_eq!(format!("{e:?}"), "s");
        assert_eq!(format!("{e:#}"), "s");
    }
}
