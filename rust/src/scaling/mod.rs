//! Scaling model (DESIGN.md §4 substitution): this box has ONE core, so
//! Figures 9–11 cannot be measured as thread sweeps. Instead we run the
//! real code path once to calibrate per-block/per-byte costs, and replay
//! the paper's exact scheduling policy (OpenMP static chunks; MPI exscan +
//! shared-file write) through a discrete cost model. The *code under
//! test* (pipeline, collectives, writer) is exercised for real elsewhere
//! (tests + examples); only multi-core *timing* is modeled here.
//!
//! Model components, in the paper's terms:
//! * per-thread work = its share of blocks x calibrated stage-1/stage-2
//!   cost; OpenMP static scheduling => max over threads + imbalance;
//! * a memory-contention factor (cores share DRAM bandwidth) bounded by
//!   the machine's stream bandwidth — this is what bends Fig 9's speedup;
//! * MPI exscan = log2(p) latency hops; file write = bytes / BW(nodes),
//!   with BW saturating at the filesystem's effective peak (Fig 11).

/// Calibrated single-core costs, measured by running the real pipeline.
#[derive(Clone, Copy, Debug)]
pub struct Calibration {
    /// Seconds of stage-1 work per block.
    pub t1_per_block: f64,
    /// Seconds of stage-2 work per raw (uncompressed chunk) byte.
    pub t2_per_byte: f64,
    /// Raw chunk bytes produced per block (stage-1 output).
    pub stage1_bytes_per_block: f64,
    /// Fraction of stage-1 time that is memory-bound (drives contention).
    pub mem_bound_frac: f64,
}

/// Platform description for the model (documented constants; the paper's
/// Piz Daint node: 12-core Xeon E5-2690v3, Sonexion 3000 FS).
#[derive(Clone, Copy, Debug)]
pub struct Platform {
    /// Per-core DRAM bandwidth share saturates at this many cores.
    pub mem_saturation_cores: f64,
    /// Exscan/barrier latency per hop (seconds).
    pub collective_hop_secs: f64,
    /// Single-node effective write bandwidth (bytes/s).
    pub node_write_bw: f64,
    /// Filesystem aggregate effective peak (bytes/s) — Fig 11's ceiling.
    pub fs_peak_bw: f64,
}

impl Platform {
    /// Piz-Daint-like constants scaled to this testbed: the shape (where
    /// contention and saturation bite) follows the paper's system, the
    /// absolute bandwidth comes from a local measurement.
    pub fn daint_like(measured_disk_bw: f64) -> Self {
        Self {
            mem_saturation_cores: 8.0,
            collective_hop_secs: 5e-6,
            node_write_bw: measured_disk_bw,
            // effective peak = 81/1.4 GB/s on the real machine ~ 58 nodes'
            // worth of single-node bandwidth; keep the same ratio
            fs_peak_bw: measured_disk_bw * 58.0,
        }
    }
}

/// Predicted multicore compression time (Fig 9/10): `nblocks` split
/// statically over `p` workers.
pub fn multicore_time(cal: &Calibration, plat: &Platform, nblocks: usize, p: usize) -> f64 {
    assert!(p >= 1);
    let per_block_total =
        cal.t1_per_block + cal.t2_per_byte * cal.stage1_bytes_per_block;
    // static schedule: ceil-share imbalance
    let share = nblocks.div_ceil(p);
    let ideal = share as f64 * per_block_total;
    // memory contention: the memory-bound fraction contends once more
    // cores than the bandwidth supports are active
    let contention = 1.0
        + cal.mem_bound_frac * ((p as f64 - 1.0) / plat.mem_saturation_cores).max(0.0);
    ideal * contention + (p as f64).log2().ceil() * plat.collective_hop_secs
}

/// Speedup curve over worker counts.
pub fn speedups(cal: &Calibration, plat: &Platform, nblocks: usize, ps: &[usize]) -> Vec<(usize, f64, f64)> {
    let t1 = multicore_time(cal, plat, nblocks, 1);
    ps.iter()
        .map(|&p| {
            let t = multicore_time(cal, plat, nblocks, p);
            (p, t, t1 / t)
        })
        .collect()
}

/// Weak-scaling point (Fig 11): every node compresses `raw_per_node` bytes
/// into `comp_per_node` bytes and all nodes write one shared file.
#[derive(Clone, Copy, Debug)]
pub struct WeakPoint {
    pub nodes: usize,
    pub compress_secs: f64,
    pub write_secs: f64,
    pub total_secs: f64,
    /// Equivalent I/O throughput (raw bytes moved / total time).
    pub equiv_throughput: f64,
}

/// Aggregate filesystem bandwidth available to `nodes` writers.
fn fs_bw(plat: &Platform, nodes: usize) -> f64 {
    // near-linear until the effective peak, then flat (plus a mild
    // contention tail as in measured Sonexion behaviour)
    let linear = plat.node_write_bw * nodes as f64;
    linear.min(plat.fs_peak_bw) / (1.0 + 0.002 * nodes as f64)
}

/// Weak scaling with compression (the paper's experiment) and without
/// (the HACC-IO-style baseline writes `raw_per_node` uncompressed).
pub fn weak_scaling(
    plat: &Platform,
    compress_secs_per_node: f64,
    raw_per_node: f64,
    comp_per_node: f64,
    nodes_list: &[usize],
) -> Vec<(WeakPoint, WeakPoint)> {
    nodes_list
        .iter()
        .map(|&nodes| {
            let bw = fs_bw(plat, nodes);
            let collect = (nodes as f64).log2().ceil() * plat.collective_hop_secs * 3.0;
            let write = comp_per_node * nodes as f64 / bw;
            let total = compress_secs_per_node + write + collect;
            let with = WeakPoint {
                nodes,
                compress_secs: compress_secs_per_node,
                write_secs: write,
                total_secs: total,
                equiv_throughput: raw_per_node * nodes as f64 / total,
            };
            let raw_write = raw_per_node * nodes as f64 / bw;
            let baseline = WeakPoint {
                nodes,
                compress_secs: 0.0,
                write_secs: raw_write,
                total_secs: raw_write + collect,
                equiv_throughput: raw_per_node * nodes as f64 / (raw_write + collect),
            };
            (with, baseline)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cal() -> Calibration {
        Calibration {
            t1_per_block: 1e-3,
            t2_per_byte: 5e-9,
            stage1_bytes_per_block: 20_000.0,
            mem_bound_frac: 0.3,
        }
    }

    fn plat() -> Platform {
        Platform::daint_like(500e6)
    }

    #[test]
    fn speedup_is_monotone_but_sublinear() {
        let s = speedups(&cal(), &plat(), 4096, &[1, 2, 4, 8, 12]);
        assert!((s[0].2 - 1.0).abs() < 1e-9);
        for w in s.windows(2) {
            assert!(w[1].2 > w[0].2, "monotone: {s:?}");
        }
        let (p, _, sp) = s[s.len() - 1];
        assert!(sp < p as f64, "sublinear at {p}: {sp}");
        assert!(sp > 0.55 * p as f64, "not absurdly bad at {p}: {sp}");
    }

    #[test]
    fn imbalance_hurts_odd_splits() {
        // 13 blocks over 12 workers: one worker does 2 blocks
        let t12_even = multicore_time(&cal(), &plat(), 12, 12);
        let t13 = multicore_time(&cal(), &plat(), 13, 12);
        assert!(t13 > 1.5 * t12_even);
    }

    #[test]
    fn weak_scaling_time_grows_and_throughput_saturates() {
        let pts = weak_scaling(&plat(), 2.0, 4e9, 70e6, &[1, 8, 64, 512]);
        // total time increases with nodes (paper Fig 11 left)
        for w in pts.windows(2) {
            assert!(w[1].0.total_secs >= w[0].0.total_secs * 0.999);
        }
        // compressed writes beat the raw baseline once the FS saturates
        let (with, base) = &pts[3];
        assert!(with.total_secs < base.total_secs, "{with:?} vs {base:?}");
        // equivalent throughput exceeds the physical FS bandwidth thanks to
        // compression (the paper's 190 GB/s claim mechanism)
        assert!(with.equiv_throughput > fs_bw(&plat(), 512));
    }

    #[test]
    fn baseline_matches_bw_at_one_node() {
        let pts = weak_scaling(&plat(), 2.0, 4e9, 70e6, &[1]);
        let (_, base) = &pts[0];
        let expect = 4e9 / fs_bw(&plat(), 1);
        assert!((base.write_secs - expect).abs() < 1e-6);
    }
}
