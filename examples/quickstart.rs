//! Quickstart: generate a small cavitation snapshot, compress the pressure
//! field with the paper's production scheme (W³ai + byte shuffle + zlib),
//! decompress it and report CR + PSNR.
//!
//! Run: `cargo run --release --example quickstart`
use cubismz::metrics::psnr;
use cubismz::pipeline::{compress_field, decompress_field, NativeEngine, PipelineConfig};
use cubismz::sim::{step_to_time, CloudConfig, CloudSim, Qoi};

fn main() {
    // 1. a 128^3 bubble-cloud snapshot shortly before collapse
    let sim = CloudSim::new(CloudConfig::paper(128));
    let field = sim.field(Qoi::Pressure, step_to_time(5000));
    println!("field: {}^3 cells, {:.1} MB raw", field.nx, field.nbytes() as f64 / 1e6);

    // 2. the paper's scheme: third-order average-interpolating wavelets,
    //    eps = 1e-3 relative, byte shuffle, zlib
    let cfg = PipelineConfig::paper_default(1e-3);
    let t = std::time::Instant::now();
    let (bytes, stats) = compress_field(&field, "p", &cfg, &NativeEngine);
    let secs = t.elapsed().as_secs_f64();
    println!(
        "compressed: {} -> {} bytes  CR {:.1}x  ({:.0} MB/s)",
        stats.raw_bytes,
        stats.compressed_bytes,
        stats.ratio(),
        stats.raw_bytes as f64 / 1e6 / secs
    );

    // 3. decompress and check fidelity
    let (back, _) = decompress_field(&bytes, &NativeEngine).expect("decompress");
    println!("PSNR: {:.1} dB", psnr(&field.data, &back.data).expect("psnr defined"));
}
