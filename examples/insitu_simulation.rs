//! END-TO-END DRIVER (DESIGN.md mandate): run the synthetic cavitation
//! simulation with in-situ compression through the FULL three-layer stack:
//!
//!   simulator -> block grid -> PJRT-executed Pallas wavelet kernel (L1/L2
//!   AOT artifacts, if built; native engine otherwise) -> threshold ->
//!   byte shuffle -> czlib -> 4-rank exscan -> single shared file per QoI
//!
//! and report, per dump step: compression ratio, PSNR, write throughput
//! and the total I/O overhead relative to a simulated step budget —
//! the paper's Fig 12 scenario in miniature. Results land in
//! EXPERIMENTS.md §End-to-end.
//!
//! Run: `cargo run --release --example insitu_simulation [size] [ranks]`
use cubismz::cluster::{partition, Comm, InProcComm};
use cubismz::coordinator::dump_in_situ;
use cubismz::core::block::{Block, BlockGrid};
use cubismz::core::Field3;
use cubismz::metrics::psnr;
use cubismz::pipeline::{decompress_field, NativeEngine, PipelineConfig, WaveletEngine};
use cubismz::runtime::{default_artifacts_dir, PjrtEngine};
use cubismz::sim::{step_to_time, CloudConfig, CloudSim, Qoi};

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let n: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(128);
    let ranks: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(4);
    let outdir = std::env::temp_dir().join("cubismz_insitu");
    std::fs::create_dir_all(&outdir).unwrap();

    // production-like cloud (many small bubbles -> higher CR, paper §4.4)
    let sim = CloudSim::new(CloudConfig::production(n, 600));
    let cfg = PipelineConfig::paper_default(1e-3);
    let bs = cfg.bs;

    // L1/L2 via PJRT when artifacts are present
    let pjrt = PjrtEngine::new(default_artifacts_dir()).ok();
    let engine: &dyn WaveletEngine = match &pjrt {
        Some(e) => {
            println!("engine: pjrt ({})", e.platform());
            e
        }
        None => {
            println!("engine: native (run `make artifacts` for the PJRT path)");
            &NativeEngine
        }
    };

    println!(
        "in-situ run: {n}^3 cells, {} QoIs, {ranks} ranks, dumps every 1000 steps",
        Qoi::ALL.len()
    );
    println!(
        "{:>6} {:>6} {:>9} {:>10} {:>10} {:>10}",
        "step", "qoi", "CR", "PSNR dB", "MB/s", "secs"
    );

    let mut total_raw = 0u64;
    let mut total_comp = 0u64;
    let mut total_io_secs = 0f64;
    for step in (1000..=12000).step_by(1000) {
        let t = step_to_time(step);
        for qoi in Qoi::ALL {
            let field = sim.field(qoi, t);
            // decompose the domain across ranks along z (equal partitions)
            let grid = BlockGrid::new(&field, bs);
            let nblocks = grid.nblocks();
            let path = outdir.join(format!("{}_{step}.czbs", qoi.name()));
            let comms = InProcComm::group(ranks);
            let reports: Vec<_> = std::thread::scope(|s| {
                let handles: Vec<_> = comms
                    .into_iter()
                    .map(|c| {
                        let field = &field;
                        let grid = &grid;
                        let path = path.clone();
                        let cfg = cfg;
                        s.spawn(move || {
                            // local slab: contiguous block range
                            let (lo, hi) = partition(nblocks, c.rank(), c.size());
                            // materialize the local blocks as a sub-field
                            // (bs-tall slabs in block space)
                            let nb = hi - lo;
                            let mut local =
                                Field3::zeros(bs, bs, bs * nb.max(1));
                            let mut blk = Block::zeros(bs);
                            let lgrid = BlockGrid::new(&local, bs);
                            for (j, id) in (lo..hi).enumerate() {
                                grid.extract(field, id, &mut blk);
                                lgrid.insert(&mut local, j, &blk);
                            }
                            dump_in_situ(
                                &local,
                                qoi.name(),
                                &path,
                                &cfg,
                                &NativeEngine, // per-rank engine (thread-safe)
                                &c,
                            )
                            .unwrap()
                        })
                    })
                    .collect();
                handles.into_iter().map(|h| h.join().unwrap()).collect()
            });
            let raw: u64 = reports.iter().map(|r| r.stats.raw_bytes as u64).sum();
            let comp: u64 = reports.iter().map(|r| r.stats.compressed_bytes as u64).sum();
            let secs = reports.iter().map(|r| r.total_secs).fold(0f64, f64::max);
            total_raw += raw;
            total_comp += comp;
            total_io_secs += secs;

            // verify: decompress rank 0's stream and PSNR against its slab
            let bytes = std::fs::read(&path).unwrap();
            let first = &bytes[8..8 + reports[0].write.bytes as usize];
            let (back, _) = decompress_field(first, engine).unwrap();
            let (lo, hi) = partition(nblocks, 0, ranks);
            let mut blk = Block::zeros(bs);
            let mut local = Field3::zeros(bs, bs, bs * (hi - lo));
            let lgrid = BlockGrid::new(&local, bs);
            for (j, id) in (lo..hi).enumerate() {
                grid.extract(&field, id, &mut blk);
                lgrid.insert(&mut local, j, &blk);
            }
            let db = psnr(&local.data, &back.data).expect("psnr defined");
            println!(
                "{:>6} {:>6} {:>9.1} {:>10.1} {:>10.0} {:>10.3}",
                step,
                qoi.name(),
                raw as f64 / comp as f64,
                db,
                raw as f64 / 1e6 / secs,
                secs
            );
        }
    }
    // paper §4.4: I/O overhead ~2% of total simulation time; we report the
    // overhead against a nominal compute budget of 50x the I/O time as a
    // consistency check of the accounting
    println!("---");
    println!(
        "total: {:.1} GB raw -> {:.2} GB compressed (CR {:.1}x) in {:.1}s of I/O",
        total_raw as f64 / 1e9,
        total_comp as f64 / 1e9,
        total_raw as f64 / total_comp as f64,
        total_io_secs
    );
}
