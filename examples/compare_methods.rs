//! Compare the four lossy compression methods (wavelets, ZFP, SZ, FPZIP)
//! on one dataset — the paper's §3.3 testbed role of CubismZ, in miniature.
//!
//! Run: `cargo run --release --example compare_methods [size] [step]`
use cubismz::codec::Codec;
use cubismz::metrics::psnr;
use cubismz::pipeline::{
    compress_field, decompress_field, CoeffCodec, NativeEngine, PipelineConfig, ShuffleMode,
    Stage1,
};
use cubismz::sim::{step_to_time, CloudConfig, CloudSim, Qoi};
use cubismz::wavelet::WaveletKind;

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let n: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(128);
    let step: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(10000);
    let sim = CloudSim::new(CloudConfig::paper(n));

    println!("method comparison, {n}^3 cells, step {step} (collapse at 7000)");
    for qoi in Qoi::ALL {
        let f = sim.field(qoi, step_to_time(step));
        println!("--- {} ---", qoi.name());
        println!("{:28} {:>9} {:>11} {:>9} {:>9}", "scheme", "CR", "PSNR (dB)", "comp s", "dec s");
        for (label, stage1, stage2, shuffle) in [
            (
                "W3ai + shuf + zlib",
                Stage1::Wavelet {
                    kind: WaveletKind::Avg3,
                    eps_rel: 1e-3,
                    zbits: 0,
                    coeff: CoeffCodec::None,
                },
                Codec::ZlibDef,
                ShuffleMode::Byte4,
            ),
            ("zfp (accuracy)", Stage1::Zfp { tol_rel: 1e-3 }, Codec::None, ShuffleMode::None),
            ("sz (abs bound)", Stage1::Sz { eb_rel: 1e-3 }, Codec::None, ShuffleMode::None),
            ("fpzip (20 bits)", Stage1::Fpzip { prec: 20 }, Codec::None, ShuffleMode::None),
        ] {
            let cfg = PipelineConfig::new(32, stage1, stage2).with_shuffle(shuffle);
            let t = std::time::Instant::now();
            let (bytes, st) = compress_field(&f, qoi.name(), &cfg, &NativeEngine);
            let tc = t.elapsed().as_secs_f64();
            let t = std::time::Instant::now();
            let (back, _) = decompress_field(&bytes, &NativeEngine).expect("decompress");
            let td = t.elapsed().as_secs_f64();
            println!(
                "{:28} {:>9.2} {:>11.1} {:>9.2} {:>9.2}",
                label,
                st.ratio(),
                psnr(&f.data, &back.data).expect("psnr defined"),
                tc,
                td
            );
        }
    }
}
