//! Ex-situ (offline) workflow via the library API: write an h5lite
//! container (as a simulation would), then compress every dataset in it to
//! one `.czb` per quantity — the paper's standalone-tool use case — and
//! verify the files through the chunk-cached random-access reader.
//!
//! Run: `cargo run --release --example exsitu_tool`
use cubismz::coordinator::{compress_file, psnr_file};
use cubismz::core::block::{Block, BlockGrid};
use cubismz::io::h5lite;
use cubismz::pipeline::{BlockReader, NativeEngine, PipelineConfig};
use cubismz::sim::{step_to_time, CloudConfig, CloudSim, Qoi};

fn main() {
    let dir = std::env::temp_dir().join("cubismz_exsitu");
    std::fs::create_dir_all(&dir).unwrap();
    let h5 = dir.join("snapshot_10k.h5l");

    // the "simulation dump": all four QoIs at 10k steps
    let sim = CloudSim::new(CloudConfig::paper(96));
    let datasets: Vec<h5lite::Dataset> = Qoi::ALL
        .iter()
        .map(|&q| h5lite::Dataset::from_field(q.name(), &sim.field(q, step_to_time(10000))))
        .collect();
    h5lite::write(&h5, &datasets).unwrap();
    println!("container: {} ({} datasets)", h5.display(), datasets.len());

    // offline compression of each quantity
    let cfg = PipelineConfig::paper_default(1e-3);
    for q in Qoi::ALL {
        let out = dir.join(format!("{}.czb", q.name()));
        let st = compress_file(&h5, q.name(), &out, &cfg, &NativeEngine).unwrap();
        let db = psnr_file(&h5, q.name(), &out, &NativeEngine).unwrap();
        println!(
            "{:>4}: CR {:>7.1}  PSNR {:>6.1} dB  -> {}",
            q.name(),
            st.ratio(),
            db,
            out.display()
        );
    }

    // random access through the chunk cache (the visualization path)
    let bytes = std::fs::read(dir.join("p.czb")).unwrap();
    let engine = NativeEngine;
    let mut reader = BlockReader::new(&bytes, &engine).unwrap().with_cache_capacity(4);
    let bs = reader.file.bs as usize;
    let mut blk = Block::zeros(bs);
    let field = datasets[0].to_field();
    let grid = BlockGrid::new(&field, bs);
    let some_blocks = [0u32, 7, 13, 7, 0, 1];
    for id in some_blocks {
        reader.read_block(id, &mut blk.data).unwrap();
    }
    println!(
        "random access: {} reads -> {} cache hits, {} misses",
        some_blocks.len(),
        reader.cache_hits,
        reader.cache_misses
    );
    let _ = grid;
}
