"""Pure-jnp reference oracle for the 3D lifting wavelet transform.

This file is the *specification* shared with the Rust native engine
(rust/src/wavelet/) and the Pallas kernel (wavelet3d.py) — see DESIGN.md §6.
All three must implement the identical lifting steps:

* interp4 (W4):   d = o - P4(e),                 s = e
* lift4  (W4li):  interp4 predict, then          s = e + 1/4 (d[k-1] + d[k])
* avg3   (W3ai):  s = (e + o)/2,                 d = (o - e) - P_avg3(s)

with one-sided boundary stencils ("wavelets on the interval"). The 3D
transform applies the 1D step along x, then y, then z on the leading m^3
subcube per level, m = bs >> level, down to m = 8 (coarse cube 4^3).
"""
import jax.numpy as jnp

KINDS = ("w4", "w4l", "w3a")


def max_levels(bs: int) -> int:
    lev = 0
    while (bs >> lev) > 4:
        lev += 1
    return lev


def _shift_p1(a):
    """a[k-1] with edge clamp (value at k=0 is fixed up by boundary sets)."""
    return jnp.concatenate([a[..., :1], a[..., :-1]], axis=-1)


def _shift_m1(a):
    """a[k+1] with edge clamp."""
    return jnp.concatenate([a[..., 1:], a[..., -1:]], axis=-1)


def _shift_m2(a):
    return jnp.concatenate([a[..., 2:], a[..., -2:]], axis=-1)


def pred4(e):
    """W4 predictor with one-sided cubic boundary stencils (h >= 4)."""
    em1 = _shift_p1(e)
    ep1 = _shift_m1(e)
    ep2 = _shift_m2(e)
    p = -0.0625 * em1 + 0.5625 * e + 0.5625 * ep1 - 0.0625 * ep2
    # boundaries (match rust/src/wavelet/lift1d.rs::pred4)
    p = p.at[..., 0].set(
        0.3125 * e[..., 0] + 0.9375 * e[..., 1] - 0.3125 * e[..., 2] + 0.0625 * e[..., 3]
    )
    p = p.at[..., -2].set(
        0.0625 * e[..., -4] - 0.3125 * e[..., -3] + 0.9375 * e[..., -2] + 0.3125 * e[..., -1]
    )
    # linear extrapolation at the last position (low gain: higher-order
    # one-sided stencils amplify fp noise across passes)
    p = p.at[..., -1].set(1.5 * e[..., -1] - 0.5 * e[..., -2])
    return p


def pred_avg3(s):
    """W3ai predictor of (o - e) from the averages (h >= 4)."""
    sp1 = _shift_m1(s)
    sm1 = _shift_p1(s)
    p = 0.25 * (sp1 - sm1)
    p = p.at[..., 0].set(-0.75 * s[..., 0] + 1.0 * s[..., 1] - 0.25 * s[..., 2])
    p = p.at[..., -1].set(0.75 * s[..., -1] - 1.0 * s[..., -2] + 0.25 * s[..., -3])
    return p


def lift_fwd(e, o, kind):
    if kind == "w4":
        return e, o - pred4(e)
    if kind == "w4l":
        d = o - pred4(e)
        dm1 = _shift_p1(d)  # clamp: d[-1] -> d[0]
        return e + 0.25 * (dm1 + d), d
    if kind == "w3a":
        s = 0.5 * (e + o)
        return s, (o - e) - pred_avg3(s)
    raise ValueError(kind)


def lift_inv(s, d, kind):
    if kind == "w4":
        return s, d + pred4(s)
    if kind == "w4l":
        dm1 = _shift_p1(d)
        e = s - 0.25 * (dm1 + d)
        return e, d + pred4(e)
    if kind == "w3a":
        diff = d + pred_avg3(s)
        return s - 0.5 * diff, s + 0.5 * diff
    raise ValueError(kind)


def _axis_fwd(a, m, axis, kind):
    bs = a.shape[-1]
    sub = a[:m, :m, :m] if m < bs else a
    t = jnp.moveaxis(sub, axis, -1)
    e = t[..., 0::2]
    o = t[..., 1::2]
    s, d = lift_fwd(e, o, kind)
    res = jnp.moveaxis(jnp.concatenate([s, d], axis=-1), -1, axis)
    return a.at[:m, :m, :m].set(res) if m < bs else res


def _axis_inv(a, m, axis, kind):
    bs = a.shape[-1]
    sub = a[:m, :m, :m] if m < bs else a
    t = jnp.moveaxis(sub, axis, -1)
    h = m // 2
    s = t[..., :h]
    d = t[..., h:]
    e, o = lift_inv(s, d, kind)
    # interleave e, o back
    res = jnp.stack([e, o], axis=-1).reshape(t.shape)
    res = jnp.moveaxis(res, -1, axis)
    return a.at[:m, :m, :m].set(res) if m < bs else res


def forward_3d(a, kind, levels=None):
    """Forward transform one (bs, bs, bs) block (dims ordered z, y, x)."""
    bs = a.shape[-1]
    assert a.shape == (bs, bs, bs)
    levels = max_levels(bs) if levels is None else levels
    for lev in range(levels):
        m = bs >> lev
        for axis in (2, 1, 0):  # x, then y, then z
            a = _axis_fwd(a, m, axis, kind)
    return a


def inverse_3d(a, kind, levels=None):
    bs = a.shape[-1]
    levels = max_levels(bs) if levels is None else levels
    for lev in reversed(range(levels)):
        m = bs >> lev
        for axis in (0, 1, 2):  # reverse: z, then y, then x
            a = _axis_inv(a, m, axis, kind)
    return a


def forward_batch(x, kind, levels=None):
    """x: (n, bs, bs, bs) -> transformed batch."""
    import jax

    return jax.vmap(lambda b: forward_3d(b, kind, levels))(x)


def inverse_batch(x, kind, levels=None):
    import jax

    return jax.vmap(lambda b: inverse_3d(b, kind, levels))(x)
