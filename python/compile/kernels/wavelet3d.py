"""Layer-1 Pallas kernel: batched 3D lifting wavelet transform.

One grid program per block; the (1, bs, bs, bs) tile is the Pallas
BlockSpec unit — on TPU this is the HBM->VMEM schedule (a 32^3 f32 block
is 128 KiB, exactly the cache-resident unit the paper tunes for; see
DESIGN.md §Hardware-Adaptation). The whole multi-level transform runs on
the VMEM-resident tile; the lifting steps are elementwise adds/muls
(VPU work, no MXU), so the kernel is memory-bound by design.

interpret=True is REQUIRED on this CPU-only environment: real TPU
lowering emits a Mosaic custom-call the CPU PJRT plugin cannot execute.
"""
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref


def _fwd_kernel(x_ref, o_ref, *, kind: str, levels: int):
    a = x_ref[0]
    bs = a.shape[-1]
    for lev in range(levels):
        m = bs >> lev
        for axis in (2, 1, 0):
            a = ref._axis_fwd(a, m, axis, kind)
    o_ref[0] = a


def _inv_kernel(x_ref, o_ref, *, kind: str, levels: int):
    a = x_ref[0]
    bs = a.shape[-1]
    for lev in reversed(range(levels)):
        m = bs >> lev
        for axis in (0, 1, 2):
            a = ref._axis_inv(a, m, axis, kind)
    o_ref[0] = a


def _pallas_transform(x, kind: str, inverse: bool, levels=None):
    n, bs = x.shape[0], x.shape[-1]
    assert x.shape == (n, bs, bs, bs), x.shape
    lv = ref.max_levels(bs) if levels is None else levels
    # Pallas interpret-mode quirk: a single-program grid (grid=(1,)) with
    # multi-level in-place `.at[]` updates produces wrong values for
    # bs >= 16 (the XLA-compiled lowering of the same kernel is correct —
    # see rust/tests/pjrt_parity.rs). Pad single-block batches to 2.
    padded = n == 1
    if padded:
        x = jnp.concatenate([x, x], axis=0)
        n = 2
    kernel = functools.partial(_inv_kernel if inverse else _fwd_kernel, kind=kind, levels=lv)
    out = pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.float32),
        grid=(n,),
        in_specs=[pl.BlockSpec((1, bs, bs, bs), lambda i: (i, 0, 0, 0))],
        out_specs=pl.BlockSpec((1, bs, bs, bs), lambda i: (i, 0, 0, 0)),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x)
    return out[:1] if padded else out


def forward(x, kind: str, levels=None):
    """Forward-transform a (n, bs, bs, bs) batch via the Pallas kernel."""
    return _pallas_transform(x, kind, inverse=False, levels=levels)


def inverse(x, kind: str, levels=None):
    """Inverse-transform a (n, bs, bs, bs) batch via the Pallas kernel."""
    return _pallas_transform(x, kind, inverse=True, levels=levels)
