"""Layer-2 JAX compute graph: the batched wavelet transforms the Rust
coordinator executes via PJRT. Thin by design — this paper's contribution
is the coordination layer (L3) and the transform kernel (L1); L2 simply
exposes jit-able entry points that lower to a single fused HLO module per
(direction, wavelet, batch) variant."""
import jax.numpy as jnp

from .kernels import ref, wavelet3d


def wavelet_forward(kind: str):
    """Returns f(x: f32[n, bs, bs, bs]) -> (coeffs,) using the L1 kernel."""

    def fn(x):
        return (wavelet3d.forward(x.astype(jnp.float32), kind),)

    return fn


def wavelet_inverse(kind: str):
    def fn(x):
        return (wavelet3d.inverse(x.astype(jnp.float32), kind),)

    return fn


def wavelet_forward_ref(kind: str):
    """Pure-jnp variant (no Pallas) — used to cross-check lowering."""

    def fn(x):
        return (ref.forward_batch(x.astype(jnp.float32), kind),)

    return fn
