"""AOT lowering: jax/Pallas -> HLO *text* artifacts for the Rust runtime.

HLO text (NOT lowered.compiler_ir("hlo") protos or .serialize()) is the
interchange format: jax >= 0.5 emits protos with 64-bit instruction ids
that the xla crate's xla_extension 0.5.1 rejects; the text parser
reassigns ids (see /opt/xla-example/README.md).

Artifacts (batch n, block size 32, f32):
  wavelet_{fwd|inv}_{w4|w4l|w3a}_b32_n{1,16}.hlo.txt

Also exports cross-language test vectors consumed by `cargo test`:
  testvectors/wavelet_{kind}_b32.bin
    layout: u32 bs | u32 nblocks | input f32[n*bs^3] | fwd f32[n*bs^3]
"""
import argparse
import pathlib
import struct

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model
from .kernels import ref

BS = 32
BATCHES = (1, 16)
KINDS = ("w4", "w4l", "w3a")


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(kind: str, inverse: bool, n: int) -> str:
    fn = model.wavelet_inverse(kind) if inverse else model.wavelet_forward(kind)
    spec = jax.ShapeDtypeStruct((n, BS, BS, BS), jnp.float32)
    return to_hlo_text(jax.jit(fn).lower(spec))


def write_test_vectors(out_dir: pathlib.Path) -> None:
    tv_dir = out_dir / "testvectors"
    tv_dir.mkdir(parents=True, exist_ok=True)
    rng = np.random.default_rng(0xC0FFEE)
    n = 3
    for kind in KINDS:
        x = rng.uniform(-50.0, 50.0, size=(n, BS, BS, BS)).astype(np.float32)
        fwd = np.asarray(ref.forward_batch(jnp.asarray(x), kind), dtype=np.float32)
        path = tv_dir / f"wavelet_{kind}_b{BS}.bin"
        with open(path, "wb") as f:
            f.write(struct.pack("<II", BS, n))
            f.write(x.tobytes())
            f.write(fwd.tobytes())
        print(f"wrote {path}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--skip-vectors", action="store_true")
    args = ap.parse_args()
    out_dir = pathlib.Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    for kind in KINDS:
        for n in BATCHES:
            for inverse in (False, True):
                tag = "inv" if inverse else "fwd"
                text = lower_variant(kind, inverse, n)
                path = out_dir / f"wavelet_{tag}_{kind}_b{BS}_n{n}.hlo.txt"
                path.write_text(text)
                print(f"wrote {path} ({len(text)} chars)")
    if not args.skip_vectors:
        write_test_vectors(out_dir)


if __name__ == "__main__":
    main()
