"""Kernel-vs-oracle correctness: the CORE signal that the Pallas kernel
(L1) implements the DESIGN.md §6 wavelet spec, plus hypothesis sweeps over
shapes/kinds and reconstruction properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, wavelet3d

KINDS = ("w4", "w4l", "w3a")


def rand_batch(rng, n, bs, lo=-50.0, hi=50.0):
    return rng.uniform(lo, hi, size=(n, bs, bs, bs)).astype(np.float32)


@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("bs", [8, 16, 32])
def test_pallas_forward_matches_ref(kind, bs):
    rng = np.random.default_rng(42)
    x = jnp.asarray(rand_batch(rng, 2, bs))
    got = wavelet3d.forward(x, kind)
    want = ref.forward_batch(x, kind)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=5e-4)


@pytest.mark.parametrize("kind", KINDS)
@pytest.mark.parametrize("bs", [8, 16, 32])
def test_pallas_inverse_matches_ref(kind, bs):
    rng = np.random.default_rng(43)
    x = jnp.asarray(rand_batch(rng, 2, bs))
    got = wavelet3d.inverse(x, kind)
    want = ref.inverse_batch(x, kind)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=5e-4)


@pytest.mark.parametrize("kind", KINDS)
def test_roundtrip_reconstruction(kind):
    rng = np.random.default_rng(44)
    x = jnp.asarray(rand_batch(rng, 2, 32))
    back = wavelet3d.inverse(wavelet3d.forward(x, kind), kind)
    np.testing.assert_allclose(np.asarray(back), np.asarray(x), rtol=0, atol=2e-3)


@settings(max_examples=8, deadline=None)
@given(
    kind=st.sampled_from(KINDS),
    bs_pow=st.integers(min_value=3, max_value=5),  # bs in {8, 16, 32}
    n=st.integers(min_value=1, max_value=3),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
    scale=st.sampled_from([1e-3, 1.0, 1e4]),
)
def test_hypothesis_kernel_matches_ref_and_reconstructs(kind, bs_pow, n, seed, scale):
    bs = 1 << bs_pow
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rand_batch(rng, n, bs, -scale, scale))
    fwd_k = np.asarray(wavelet3d.forward(x, kind))
    fwd_r = np.asarray(ref.forward_batch(x, kind))
    np.testing.assert_allclose(fwd_k, fwd_r, rtol=1e-3, atol=2e-4 * scale)
    back = np.asarray(ref.inverse_batch(jnp.asarray(fwd_r), kind))
    np.testing.assert_allclose(back, np.asarray(x), rtol=0, atol=2e-4 * scale)


@pytest.mark.parametrize("kind", KINDS)
def test_constant_block_has_zero_details(kind):
    x = jnp.full((1, 16, 16, 16), 3.25, dtype=jnp.float32)
    c = np.asarray(ref.forward_batch(x, kind))[0]
    # everything outside the coarse 4^3 cube must vanish exactly
    mask = np.ones((16, 16, 16), dtype=bool)
    mask[:4, :4, :4] = False
    assert np.all(c[mask] == 0.0)


def test_partial_levels_identity():
    rng = np.random.default_rng(45)
    x = jnp.asarray(rand_batch(rng, 1, 16))
    same = ref.forward_batch(x, "w3a", levels=0)
    np.testing.assert_array_equal(np.asarray(same), np.asarray(x))


def test_smooth_field_detail_energy_is_small():
    # energy compaction on a smooth field (what makes the paper's CR high)
    bs = 32
    z, y, x = np.mgrid[0:bs, 0:bs, 0:bs].astype(np.float32) / bs
    f = (np.sin(6.28 * x) * np.cos(6.28 * y) * np.sin(6.28 * z) * 10.0).astype(np.float32)
    c = np.asarray(ref.forward_3d(jnp.asarray(f), "w4"))
    total = float((c.astype(np.float64) ** 2).sum())
    coarse = float((c[:4, :4, :4].astype(np.float64) ** 2).sum())
    assert coarse > 0.45 * total, f"coarse energy {coarse / total:.3f}"


def test_batch_entries_are_independent():
    rng = np.random.default_rng(46)
    x = rand_batch(rng, 3, 16)
    full = np.asarray(wavelet3d.forward(jnp.asarray(x), "w3a"))
    for i in range(3):
        one = np.asarray(wavelet3d.forward(jnp.asarray(x[i : i + 1]), "w3a"))
        np.testing.assert_array_equal(full[i], one[0])


def test_jit_lowering_produces_hlo_text():
    # the aot.py path end-to-end for one small variant
    from compile import aot, model

    fn = model.wavelet_forward("w3a")
    spec = jax.ShapeDtypeStruct((1, 8, 8, 8), jnp.float32)
    text = aot.to_hlo_text(jax.jit(fn).lower(spec))
    assert "HloModule" in text
    assert len(text) > 1000
