#!/usr/bin/env python3
"""Bench trend diff for the BENCH_*.json files the benches emit.

Usage: bench_trend.py PREV_DIR CUR_DIR

Compares every BENCH_*.json present in CUR_DIR against the same-named
file in PREV_DIR (a previous CI run's artifact) and prints per-metric
deltas. Missing files, malformed JSON, or schema drift are reported and
skipped.

By default the script is fail-soft: it always exits 0, so a broken trend
check can never fail the build (what CI runs). With BENCH_TREND_STRICT=1
in the environment — intended for local use before sending a perf-
sensitive change — any metric that regressed by more than 25% makes the
script exit nonzero after printing the full diff. Two metric families
are direction-aware:

* lower-is-better keys (ending in "_ms" or "_err", or containing
  "p50"/"p99"/"latency"): a >25% *increase* is a regression;
* higher-is-better keys (containing "mbps", "speedup", "per_sec",
  "psnr", or a compression-ratio key "cr"/"ratio"): a >25% *drop* is a
  regression — BENCH_quality.json rows trend achieved quality this way.

Lower-is-better wins when a key matches both families, so a name like
"p99_latency_per_sec" is never scored backwards.
"""
import glob
import json
import os
import sys

STRICT = os.environ.get("BENCH_TREND_STRICT") == "1"
# >25% move in the bad direction = regression (drop for throughput,
# rise for latency)
REGRESSION_FRACTION = 0.25
REGRESSIONS = []


def is_throughput_key(key):
    """Higher-is-better: throughput, and quality metrics (PSNR, CR)."""
    k = key.lower()
    return (
        "mbps" in k
        or "speedup" in k
        or "per_sec" in k
        or "psnr" in k
        or k == "cr"
        or "ratio" in k
    )


def is_latency_key(key):
    """Lower-is-better: latency, and achieved-error metrics."""
    k = key.lower()
    return (
        k.endswith("_ms")
        or k.endswith("_err")
        or "p50" in k
        or "p99" in k
        or "latency" in k
    )


def note_regression(context, key, old, new):
    if not isinstance(old, (int, float)) or not isinstance(new, (int, float)):
        return
    if old <= 0:
        return
    # latency first: it wins when a key matches both families
    if is_latency_key(key):
        if new > old * (1.0 + REGRESSION_FRACTION):
            REGRESSIONS.append(f"{context} {key}: {old:.3g} -> {new:.3g} (latency up)")
    elif is_throughput_key(key):
        if new < old * (1.0 - REGRESSION_FRACTION):
            REGRESSIONS.append(f"{context} {key}: {old:.3g} -> {new:.3g}")


def load(path):
    try:
        with open(path) as f:
            return json.load(f)
    except Exception as e:  # fail-soft by contract
        print(f"  ! could not read {path}: {e}")
        return None


def fmt_delta(old, new):
    if not isinstance(old, (int, float)) or not isinstance(new, (int, float)):
        return None
    if old == 0:
        return f"{old} -> {new}"
    pct = 100.0 * (new - old) / abs(old)
    return f"{old:.3g} -> {new:.3g} ({pct:+.1f}%)"


def row_key(row):
    """Stable identity for a row across runs: the composite of every
    identity-like field present, so rows that share e.g. a thread count
    but differ in simd mode never collide."""
    key = tuple(
        (k, row[k])
        for k in ("threads", "eps", "cache_chunks", "name", "field", "simd", "bound", "codec")
        if k in row
    )
    return key or None


def diff_rows(label, old_rows, new_rows, indent="  "):
    old_by_key = {row_key(r): r for r in old_rows if row_key(r) is not None}
    for new in new_rows:
        key = row_key(new)
        old = old_by_key.get(key)
        label_str = ",".join(f"{k}={v}" for k, v in (key or ()))
        if old is None:
            print(f"{indent}{label_str}: (new row)")
            continue
        key_fields = {k for k, _ in key}
        parts = []
        for k, v in new.items():
            if k in key_fields:
                continue
            d = fmt_delta(old.get(k), v)
            if d is not None:
                parts.append(f"{k} {d}")
                note_regression(f"{label} {label_str}", k, old.get(k), v)
        print(f"{indent}{label_str}: " + ("; ".join(parts) if parts else "(no numeric fields)"))


def main():
    if len(sys.argv) != 3:
        print(__doc__)
        return
    prev_dir, cur_dir = sys.argv[1], sys.argv[2]
    cur_files = sorted(glob.glob(os.path.join(cur_dir, "BENCH_*.json")))
    if not cur_files:
        print(f"no BENCH_*.json in {cur_dir}; nothing to compare")
        return
    for cur_path in cur_files:
        name = os.path.basename(cur_path)
        print(f"== {name} ==")
        prev_path = os.path.join(prev_dir, name)
        if not os.path.exists(prev_path):
            print("  (no previous run to compare against)")
            continue
        cur, prev = load(cur_path), load(prev_path)
        if cur is None or prev is None:
            continue
        try:
            for k, v in cur.items():
                pv = prev.get(k)
                # any top-level list of row objects diffs row-by-row:
                # "rows", but also named sections like "cache_sweep"
                # (dataset_scaling) or "single_chunk_stage2"
                # (thread_scaling)
                if isinstance(v, list) and isinstance(pv, list) and v and isinstance(v[0], dict):
                    if k != "rows":
                        print(f"  [{k}]")
                    diff_rows(name, pv, v)
                    continue
                d = fmt_delta(pv, v)
                if d is not None and pv != v:
                    print(f"  {k}: {d}")
                    note_regression(name, k, pv, v)
        except Exception as e:  # fail-soft by contract
            print(f"  ! diff failed: {e}")
    if REGRESSIONS:
        print(f"regressions > {int(REGRESSION_FRACTION * 100)}%:")
        for r in REGRESSIONS:
            print(f"  !! {r}")
        if STRICT:
            print("BENCH_TREND_STRICT=1: failing on the regressions above")
            sys.exit(1)
    if STRICT:
        print("(strict mode: no regression above the threshold)")
    else:
        print("(trend diff is informational only; set BENCH_TREND_STRICT=1 to fail on >25% throughput/latency regressions)")


if __name__ == "__main__":
    main()
