#!/usr/bin/env python3
"""Docs reference checker (CI leg).

Two invariants, both directions:
  1. Every `docs/<name>.md` referenced from Rust sources, tests,
     README.md or another doc actually exists.
  2. Every file under docs/ is referenced from at least one Rust
     source/test or README.md -- orphaned docs rot.

No dependencies; run from anywhere inside the repo.
"""
import re
import sys
from pathlib import Path

REF = re.compile(r"docs/([A-Za-z0-9_.-]+\.md)")


def repo_root() -> Path:
    p = Path(__file__).resolve().parent.parent
    if not (p / "docs").is_dir():
        sys.exit(f"check_docs: cannot locate repo root from {p}")
    return p


def refs_in(path: Path) -> set[str]:
    return set(REF.findall(path.read_text(encoding="utf-8", errors="replace")))


def main() -> int:
    root = repo_root()
    docs = {p.name for p in (root / "docs").glob("*.md")}

    source_files = sorted((root / "rust").rglob("*.rs")) + [root / "README.md"]
    doc_files = sorted((root / "docs").glob("*.md"))

    errors = []
    referenced_from_source: set[str] = set()
    for f in source_files + doc_files:
        for name in refs_in(f):
            if name not in docs:
                errors.append(f"{f.relative_to(root)}: references docs/{name}, which does not exist")
            if f in source_files:
                referenced_from_source.add(name)

    for name in sorted(docs - referenced_from_source):
        errors.append(f"docs/{name}: not referenced from any Rust source or README.md")

    for e in errors:
        print(f"check_docs: {e}", file=sys.stderr)
    if not errors:
        print(f"check_docs: ok ({len(docs)} docs, {len(source_files)} source files scanned)")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
